//! Shared utilities: deterministic PRNGs, statistics, JSON, timing.

pub mod json;
pub mod prng;
pub mod stats;
pub mod timer;

pub use json::Json;
pub use prng::{Rng, SplitMix64};
pub use stats::{Histogram, Summary};
pub use timer::Timer;
