//! Deterministic PRNGs for workloads, weight init and property tests.
//!
//! No external `rand` crate is available offline, so we carry our own
//! SplitMix64 (seeding) + Xoshiro256** (bulk) implementation — both are
//! public-domain algorithms with published test vectors (checked in the
//! unit tests below).

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the reference implementation's guidance.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (adequate for synthetic workloads).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fill a slice with i.i.d. normals scaled by `scale`.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * scale;
        }
    }

    /// Fill with uniform values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform_in(lo, hi);
        }
    }

    /// Random boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Reference sequence for seed 1234567 (from the public-domain
        // splitmix64.c reference implementation).
        let mut sm = SplitMix64::new(1234567);
        let got: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                6457827717110365317,
                3203168211198807973,
                9817491932198370423
            ]
        );
    }

    #[test]
    fn xoshiro_is_deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
