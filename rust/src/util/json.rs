//! Minimal JSON parser for the AOT `manifest.json` (serde is unavailable
//! offline).  Supports the full JSON grammar we emit: objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj["key"]` as &str or an error naming the key (manifest parsing).
    pub fn str_field(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| format!("missing/invalid string field {key:?}"))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize, String> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("missing/invalid integer field {key:?}"))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => {
                    match self.bump().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad surrogate"));
                                }
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(
                                ch.ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                b if b < 0x20 => return Err(self.err("control in string")),
                b => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match b {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("bad utf8"))?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_json() {
        let j = Json::parse(
            r#"{"version":1,"entries":[{"file":"a.hlo.txt","block":16,
                "inputs":[{"name":"w","shape":[1536,512]}]}]}"#,
        )
        .unwrap();
        assert_eq!(j.usize_field("version").unwrap(), 1);
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.str_field("file").unwrap(), "a.hlo.txt");
        assert_eq!(e.usize_field("block").unwrap(), 16);
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 1536);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nbA😀""#).unwrap(),
            Json::Str("a\nbA😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn nested_arrays_and_unicode_passthrough() {
        let j = Json::parse(r#"[[1,2],[3,[4]],"héllo"]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].as_str().unwrap(), "héllo");
    }
}
