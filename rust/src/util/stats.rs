//! Small statistics toolkit for the bench harness and coordinator metrics.

/// Streaming summary of a set of samples (nanoseconds, counts, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|v| (v - m) * (v - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// Percentile in `[0, 100]` with linear interpolation.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile out of range");
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        if n == 1 {
            return self.samples[0];
        }
        let rank = p / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }
}

/// Fixed-bound histogram for latency tracking in the coordinator (lock-free
/// readers not needed; the coordinator owns it behind a mutex).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds (exclusive), ascending; last bucket is +inf.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// Exponential buckets: `lo * ratio^k` until `hi`.
    pub fn exponential(lo: f64, hi: f64, ratio: f64) -> Self {
        assert!(lo > 0.0 && ratio > 1.0 && hi > lo);
        let mut bounds = Vec::new();
        let mut b = lo;
        while b < hi {
            bounds.push(b);
            b *= ratio;
        }
        let n = bounds.len() + 1;
        Self {
            bounds,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
        }
    }

    pub fn record(&mut self, v: f64) {
        let idx = match self
            .bounds
            .iter()
            .position(|&b| v < b)
        {
            Some(i) => i,
            None => self.counts.len() - 1,
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Upper bound of the bucket containing quantile `q` in `[0,1]`.
    pub fn quantile_bound(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    f64::INFINITY
                };
            }
        }
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for v in [4.0, 1.0, 3.0, 2.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.len(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.median() - 3.0).abs() < 1e-12);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        for v in [10.0, 20.0] {
            s.push(v);
        }
        assert!((s.percentile(50.0) - 15.0).abs() < 1e-12);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 20.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let mut h = Histogram::exponential(1.0, 1000.0, 10.0);
        for v in [0.5, 5.0, 50.0, 500.0, 5000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_bound(0.2) <= 1.0);
        assert!(h.quantile_bound(1.0).is_infinite());
        assert!((h.mean() - 1111.1).abs() < 0.1);
    }
}
