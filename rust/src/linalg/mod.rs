//! Dense linear algebra substrate (the paper's MKL/OpenBLAS substitute).
//!
//! Deterministic at any core count: the paper's experiments measure a
//! single inference stream on an embedded-class core, and the golden
//! parity tests against the JAX artifacts need reproducible floats — so
//! the multicore path ([`pool`]) only ever *partitions* output rows
//! across cores (one weight stream, shared via the LLC); it never splits
//! a reduction.  `MTSRNN_THREADS=1` is the exact legacy single-threaded
//! path, and any thread count produces bit-identical results.
//!
//! Two GEMM generations coexist:
//!
//! * [`pack`] + [`kernels`] — the engines' hot path: weights repacked
//!   once at construction into `PACK_MR`-row k-major panels, explicit
//!   AVX2/NEON microkernels chosen by one-time runtime detection (with a
//!   portable fallback/oracle), and a fused epilogue that applies bias +
//!   gate activations to the register tile as it stores — one pass over
//!   the `[3H, T]` gate matrix instead of three.  `B` operands are the
//!   engines' time-major frames, so no input transpose exists anymore.
//! * [`gemm`] — the original row-major blocked kernels.  Still the
//!   memsim traffic model's reference loop structure, the probe baseline
//!   for the calibrated small-`N` crossover, and the fallback path when
//!   that probe finds `gemm_bt` faster on the host.

pub mod contract;
pub mod fastmath;
pub mod gemm;
pub mod kernels;
pub mod matrix;
pub mod pack;
pub mod pool;

pub use contract::ContractError;
pub use fastmath::{fast_exp, fast_sigmoid, fast_tanh, map_exp, map_sigmoid, map_tanh};
pub use gemm::{
    add_row_bias, dot, gemm, gemm_acc, gemm_bt, gemm_bt_acc, gemm_naive, gemv, gemv_acc,
    SMALL_N_CUTOFF,
};
pub use kernels::{detect as detect_simd, detect_host, supported_tiers, Simd};
pub use matrix::{transpose_into, Matrix};
pub use pack::{
    Act, Epilogue, PackedGemm, PackedMatrix, PackedQuantGemm, PanelMask, QuantScratch, PACK_MR,
    SPARSE_KB,
};
pub use pool::ThreadPool;

/// Elementwise activations used by every engine.  `sigmoid` and `tanh`
/// are the scalar hot ops of the recurrence remainder; they operate on
/// slices so the compiler can vectorize the surrounding loop.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// In-place sigmoid over a slice.
pub fn sigmoid_slice(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = sigmoid(*v);
    }
}

/// In-place tanh over a slice.
pub fn tanh_slice(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = v.tanh();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_fixed_points() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(30.0) > 0.999_999);
        assert!(sigmoid(-30.0) < 1e-6);
        // Symmetry: s(-x) = 1 - s(x)
        for x in [-3.0f32, -0.5, 0.7, 2.2] {
            assert!((sigmoid(-x) - (1.0 - sigmoid(x))).abs() < 1e-6);
        }
    }

    #[test]
    fn slice_ops() {
        let mut v = vec![0.0f32, 1.0, -1.0];
        sigmoid_slice(&mut v);
        assert!((v[0] - 0.5).abs() < 1e-7);
        let mut w = vec![0.0f32, 1.0];
        tanh_slice(&mut w);
        assert_eq!(w[0], 0.0);
        assert!((w[1] - 1.0f32.tanh()).abs() < 1e-7);
    }
}
