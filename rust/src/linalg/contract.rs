//! Checked kernel contracts: every precondition the unsafe microkernels
//! rely on, validated at the dispatch boundary.
//!
//! The raw-pointer kernels in [`crate::linalg::kernels`] are `unsafe fn`s
//! whose `# Safety` sections promise things like "the panel slice holds
//! `np * PACK_MR * k` floats" or "the mask carries `ceil(nkb / 64)` words
//! per panel".  Those promises are upheld structurally by the packers in
//! [`crate::linalg::pack`] — but a structural argument is invisible at
//! the call site, and a refactor that breaks it corrupts memory instead
//! of failing a test.  This module makes the argument *executable*: each
//! kernel family gets a validator that re-derives every bound from first
//! principles and returns a precise [`ContractError`] on the first
//! violation.
//!
//! The validators run in two configurations:
//!
//! * **Always** in debug builds (`debug_assertions`), so every unit and
//!   parity test exercises them for free.
//! * In release builds **only** when the `checks` cargo feature is on —
//!   the hot path stays branch-free in production (the zero-overhead
//!   claim is benchmarked in `EXPERIMENTS.md` §Static-analysis).
//!
//! The typed views ([`PanelView`], [`QPanelView`], [`Q4PanelView`],
//! [`FrameView`], [`QFrameView`], [`MaskView`]) are the building blocks:
//! each couples a slice to the geometry it must satisfy, and can only be
//! constructed by a validating `new`.  The `check_*_dispatch` functions
//! compose them into the exact argument lists of the three dispatchers
//! in `kernels/mod.rs`, adding the cross-argument conditions (panel
//! range bounds, output-range disjointness, epilogue shape).
//!
//! Everything here is safe Rust and allocation-free.

use crate::linalg::kernels::Simd;
use crate::linalg::pack::{Epilogue, PACK_MR, SPARSE_KB};

/// Maximum reduction depth for q8q kernels such that the i32 accumulator
/// provably cannot overflow: `k * 127 * 127 <= i32::MAX`.  Mirrors
/// `pack::Q8_MAX_K` (assert-checked equal in this module's tests).
pub const Q8_MAX_K: usize = (i32::MAX as usize) / (127 * 127);

/// Maximum reduction depth for q4 kernels (`|w| <= 7`, `|x| <= 127`):
/// `k * 7 * 127 <= i32::MAX`.  Mirrors `pack::Q4_MAX_K`.
pub const Q4_MAX_K: usize = (i32::MAX as usize) / (7 * 127);

/// Maximum reduction depth for the AVX-VNNI q8q path.  `vpdpbusd` is
/// u8 x s8, so activations are shifted to `xu = x + 128 <= 255` and the
/// accumulator starts at `-128 * sum(w)`.  Per reduction step the
/// running magnitude grows by at most `|w| * xu + 128 * |w| <= 127 *
/// (255 + 128) = 127 * 383`, so exactness needs `k * 127 * 383 <=
/// i32::MAX`.  Mirrors `pack::VNNI_Q8_MAX_K`.
pub const VNNI_Q8_MAX_K: usize = (i32::MAX as usize) / (127 * 383);

/// Maximum reduction depth for the AVX-VNNI q4 path (`|w| <= 7`,
/// shifted activation `<= 255`, correction magnitude `128 * |w|`):
/// `k * 7 * 383 <= i32::MAX`.  Mirrors `pack::VNNI_Q4_MAX_K`.
pub const VNNI_Q4_MAX_K: usize = (i32::MAX as usize) / (7 * 383);

/// A violated kernel precondition.  Each variant names the argument at
/// fault and carries the observed vs. required geometry, so the panic
/// message a failed check produces identifies the bug without a
/// debugger.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ContractError {
    /// Panel storage length does not match `np * stride` for the
    /// family's panel stride.
    PanelLen { expected: usize, got: usize, np: usize, stride: usize },
    /// Quantized panel `kp` must be even (integer kernels walk K in
    /// pairs).
    OddKp { kp: usize },
    /// Quad-interleaved panel `kp` must be a multiple of 4 (the
    /// VNNI/sdot kernels walk K in quads) — raised when a pair-packed
    /// panel is handed to a quad-tier dispatch or vice versa.
    QuadKp { kp: usize },
    /// Shifted-activation buffer (`qshift`, VNNI only) too short for
    /// `n * kp` bytes.
    ShiftLen { expected: usize, got: usize },
    /// Per-row zero-point correction buffer (`corr`, VNNI only) must
    /// hold exactly `np * PACK_MR` entries.
    CorrLen { expected: usize, got: usize },
    /// Reduction depth exceeds the family's i32-exactness bound.
    KTooLarge { kp: usize, max: usize, family: &'static str },
    /// Frame buffer too short for `n` frames of length `k`.
    FrameLen { expected: usize, got: usize, n: usize, k: usize },
    /// Pair-broadcast buffer (`qpair`) too short for `n * kp / 2` pairs.
    PairLen { expected: usize, got: usize },
    /// Mask words-per-panel disagrees with the K geometry.
    MaskWordsPerPanel { expected: usize, got: usize, nkb: usize },
    /// Mask word storage too short for `np` panels.
    MaskLen { expected: usize, got: usize, np: usize },
    /// Panel range is not `p0 <= p1 <= np`.
    PanelRange { p0: usize, p1: usize, np: usize },
    /// `crow0` is not the first row of panel `p0` — the output sub-slice
    /// would alias a neighbouring range's rows.
    OutputRow0 { crow0: usize, expected: usize },
    /// Output sub-slice length does not cover exactly the range's rows —
    /// either truncated (out-of-bounds stores) or oversized (overlap
    /// with the next range).
    OutputLen { expected: usize, got: usize, rows: usize, n: usize },
    /// Epilogue bias length is not `m`.
    BiasLen { expected: usize, got: usize },
    /// Epilogue activation segments do not divide `m` evenly.
    ActSegments { m: usize, nacts: usize },
    /// A SIMD variant was requested on a target where its kernels are
    /// not compiled (`Avx2` off x86-64, `Neon` off aarch64).
    SimdUnavailable { simd: &'static str },
    /// A recurrence gate plane does not hold `h * stride` entries (the
    /// `[h, stride]` row-major layout the chain kernels walk).
    GateLen { expected: usize, got: usize, h: usize, stride: usize },
    /// The chain's time window `off..off + t` escapes the gate stride —
    /// the strided column loads would read a neighbouring row.
    ChainWindow { off: usize, t: usize, stride: usize },
    /// The SRU highway term reads `x[j * d + i]` for `i < h`, which
    /// requires `h <= d`.
    HighwayDim { h: usize, d: usize },
    /// A recurrent state vector (`c`, `h`) does not hold exactly `h`
    /// entries.
    StateLen { expected: usize, got: usize },
    /// The chain's output plane does not hold `stride * h` entries
    /// (time-major rows shared with the other streams in the block).
    ChainOut { expected: usize, got: usize, stride: usize, h: usize },
}

impl std::fmt::Display for ContractError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            ContractError::PanelLen { expected, got, np, stride } => write!(
                f,
                "panel storage must hold np * stride = {np} * {stride} = \
                 {expected} elements, got {got}"
            ),
            ContractError::OddKp { kp } => {
                write!(f, "quantized panel depth kp must be even (pair-walked), got {kp}")
            }
            ContractError::QuadKp { kp } => write!(
                f,
                "quad-interleaved panel depth kp must be a multiple of 4 \
                 (quad-walked), got {kp}"
            ),
            ContractError::ShiftLen { expected, got } => write!(
                f,
                "qshift buffer must hold n * kp = {expected} shifted bytes, got {got}"
            ),
            ContractError::CorrLen { expected, got } => write!(
                f,
                "corr buffer must hold np * PACK_MR = {expected} row corrections, got {got}"
            ),
            ContractError::KTooLarge { kp, max, family } => write!(
                f,
                "{family} reduction depth {kp} exceeds i32-exactness bound {max}"
            ),
            ContractError::FrameLen { expected, got, n, k } => write!(
                f,
                "frame buffer must hold n * k = {n} * {k} = {expected} elements, got {got}"
            ),
            ContractError::PairLen { expected, got } => write!(
                f,
                "qpair buffer must hold n * kp / 2 = {expected} pairs, got {got}"
            ),
            ContractError::MaskWordsPerPanel { expected, got, nkb } => write!(
                f,
                "mask words_per_panel must be ceil(nkb={nkb} / 64) = {expected}, got {got}"
            ),
            ContractError::MaskLen { expected, got, np } => write!(
                f,
                "mask must hold np * words_per_panel = {np} * wpp = {expected} words, got {got}"
            ),
            ContractError::PanelRange { p0, p1, np } => {
                write!(f, "panel range must satisfy p0 <= p1 <= np, got {p0}..{p1} of {np}")
            }
            ContractError::OutputRow0 { crow0, expected } => write!(
                f,
                "crow0 must equal p0 * PACK_MR = {expected} (disjoint-range invariant), got {crow0}"
            ),
            ContractError::OutputLen { expected, got, rows, n } => write!(
                f,
                "output sub-slice must hold rows * n = {rows} * {n} = \
                 {expected} elements, got {got}"
            ),
            ContractError::BiasLen { expected, got } => {
                write!(f, "epilogue bias must have len m = {expected}, got {got}")
            }
            ContractError::ActSegments { m, nacts } => write!(
                f,
                "epilogue activation segments must divide m evenly: m = {m}, acts = {nacts}"
            ),
            ContractError::SimdUnavailable { simd } => {
                write!(f, "SIMD variant {simd} is not compiled for this target")
            }
            ContractError::GateLen { expected, got, h, stride } => write!(
                f,
                "gate plane must hold h * stride = {h} * {stride} = {expected} entries, got {got}"
            ),
            ContractError::ChainWindow { off, t, stride } => write!(
                f,
                "chain window off + t = {off} + {t} must stay within the gate stride {stride}"
            ),
            ContractError::HighwayDim { h, d } => {
                write!(f, "SRU highway requires h <= d, got h = {h}, d = {d}")
            }
            ContractError::StateLen { expected, got } => {
                write!(f, "state vector must hold h = {expected} entries, got {got}")
            }
            ContractError::ChainOut { expected, got, stride, h } => write!(
                f,
                "chain output must hold stride * h = {stride} * {h} = {expected} entries, got {got}"
            ),
        }
    }
}

impl std::error::Error for ContractError {}

/// Number of `PACK_MR`-row panels covering `m` rows.
#[inline]
pub fn num_panels(m: usize) -> usize {
    m.div_ceil(PACK_MR)
}

/// A validated view over f32 packed panels: `np` panels of stride
/// `PACK_MR * k` (k-major, zero-padded rows).
#[derive(Debug, Clone, Copy)]
pub struct PanelView<'a> {
    pub panels: &'a [f32],
    pub m: usize,
    pub k: usize,
}

impl<'a> PanelView<'a> {
    pub fn new(panels: &'a [f32], m: usize, k: usize) -> Result<Self, ContractError> {
        let np = num_panels(m);
        let stride = PACK_MR * k;
        let expected = np * stride;
        if panels.len() != expected {
            return Err(ContractError::PanelLen { expected, got: panels.len(), np, stride });
        }
        Ok(Self { panels, m, k })
    }
}

/// A validated view over q8q pair-interleaved i8 panels: stride
/// `PACK_MR * kp` with `kp` even and within the i32-exactness bound.
#[derive(Debug, Clone, Copy)]
pub struct QPanelView<'a> {
    pub panels: &'a [i8],
    pub m: usize,
    pub kp: usize,
}

impl<'a> QPanelView<'a> {
    pub fn new(panels: &'a [i8], m: usize, kp: usize) -> Result<Self, ContractError> {
        if kp % 2 != 0 {
            return Err(ContractError::OddKp { kp });
        }
        // kp = k rounded up to even, so kp <= Q8_MAX_K + 1 iff
        // k <= Q8_MAX_K (padding columns are zero and add nothing).
        if kp > Q8_MAX_K + 1 {
            return Err(ContractError::KTooLarge { kp, max: Q8_MAX_K, family: "q8q" });
        }
        let np = num_panels(m);
        let stride = PACK_MR * kp;
        let expected = np * stride;
        if panels.len() != expected {
            return Err(ContractError::PanelLen { expected, got: panels.len(), np, stride });
        }
        Ok(Self { panels, m, kp })
    }

    /// Validate a k-quad-interleaved q8q panel (the VNNI/sdot layout):
    /// `kp % 4 == 0`, depth within the tier's i32-exactness bound
    /// (`max_k`), same `np * PACK_MR * kp` storage.  `kp = k` rounded
    /// up to a multiple of 4, so `kp <= max_k + 3` iff `k <= max_k`
    /// (pad columns are zero and add nothing).
    pub fn new_quad(
        panels: &'a [i8],
        m: usize,
        kp: usize,
        max_k: usize,
        family: &'static str,
    ) -> Result<Self, ContractError> {
        if kp % 4 != 0 {
            return Err(ContractError::QuadKp { kp });
        }
        if kp > max_k + 3 {
            return Err(ContractError::KTooLarge { kp, max: max_k, family });
        }
        let np = num_panels(m);
        let stride = PACK_MR * kp;
        let expected = np * stride;
        if panels.len() != expected {
            return Err(ContractError::PanelLen { expected, got: panels.len(), np, stride });
        }
        Ok(Self { panels, m, kp })
    }
}

/// A validated view over q4 nibble-packed panels: stride
/// `(PACK_MR / 2) * kp` bytes (two rows per byte), `kp` even, depth
/// within the q4 i32-exactness bound.
#[derive(Debug, Clone, Copy)]
pub struct Q4PanelView<'a> {
    pub panels: &'a [u8],
    pub m: usize,
    pub kp: usize,
}

impl<'a> Q4PanelView<'a> {
    pub fn new(panels: &'a [u8], m: usize, kp: usize) -> Result<Self, ContractError> {
        if kp % 2 != 0 {
            return Err(ContractError::OddKp { kp });
        }
        if kp > Q4_MAX_K + 1 {
            return Err(ContractError::KTooLarge { kp, max: Q4_MAX_K, family: "q4" });
        }
        let np = num_panels(m);
        let stride = (PACK_MR / 2) * kp;
        let expected = np * stride;
        if panels.len() != expected {
            return Err(ContractError::PanelLen { expected, got: panels.len(), np, stride });
        }
        Ok(Self { panels, m, kp })
    }

    /// Validate a k-quad nibble-packed q4 panel (the VNNI/sdot group
    /// layout): `kp % 4 == 0`, depth within the tier bound, same
    /// `np * (PACK_MR / 2) * kp` byte storage.
    pub fn new_quad(
        panels: &'a [u8],
        m: usize,
        kp: usize,
        max_k: usize,
        family: &'static str,
    ) -> Result<Self, ContractError> {
        if kp % 4 != 0 {
            return Err(ContractError::QuadKp { kp });
        }
        if kp > max_k + 3 {
            return Err(ContractError::KTooLarge { kp, max: max_k, family });
        }
        let np = num_panels(m);
        let stride = (PACK_MR / 2) * kp;
        let expected = np * stride;
        if panels.len() != expected {
            return Err(ContractError::PanelLen { expected, got: panels.len(), np, stride });
        }
        Ok(Self { panels, m, kp })
    }
}

/// A validated view over `n` time-major f32 frames of length `k`.
#[derive(Debug, Clone, Copy)]
pub struct FrameView<'a> {
    pub x: &'a [f32],
    pub n: usize,
    pub k: usize,
}

impl<'a> FrameView<'a> {
    pub fn new(x: &'a [f32], n: usize, k: usize) -> Result<Self, ContractError> {
        let expected = n * k;
        if x.len() != expected {
            return Err(ContractError::FrameLen { expected, got: x.len(), n, k });
        }
        Ok(Self { x, n, k })
    }
}

/// A validated view over quantized activation frames: `xq` holds `n`
/// i8 frames of length `kp`, and (when present) `qpair` the same data
/// as `n * kp / 2` packed i16 pairs — the two broadcast forms the
/// integer kernels consume.
#[derive(Debug, Clone, Copy)]
pub struct QFrameView<'a> {
    pub xq: &'a [i8],
    pub qpair: &'a [i32],
    pub n: usize,
    pub kp: usize,
}

impl<'a> QFrameView<'a> {
    pub fn new(
        xq: &'a [i8],
        qpair: &'a [i32],
        n: usize,
        kp: usize,
    ) -> Result<Self, ContractError> {
        if kp % 2 != 0 {
            return Err(ContractError::OddKp { kp });
        }
        let expected = n * kp;
        if xq.len() != expected {
            return Err(ContractError::FrameLen { expected, got: xq.len(), n, k: kp });
        }
        let pairs = n * kp / 2;
        if qpair.len() != pairs {
            return Err(ContractError::PairLen { expected: pairs, got: qpair.len() });
        }
        Ok(Self { xq, qpair, n, kp })
    }
}

/// A validated view over a `PanelMask::for_kernels` bitmap: `wpp` words
/// per panel consistent with the K geometry, `np * wpp` words total.
///
/// `nkb` is derived from the *kernel-visible* depth: `ceil(k /
/// SPARSE_KB)` for f32, `ceil(kp / SPARSE_KB)` for the integer families
/// (identical to the pack-time `ceil(k / SPARSE_KB)` because the single
/// pad column of an odd `k` never starts a new block).
#[derive(Debug, Clone, Copy)]
pub struct MaskView<'a> {
    pub words: &'a [u64],
    pub wpp: usize,
    pub np: usize,
}

impl<'a> MaskView<'a> {
    pub fn new(
        words: &'a [u64],
        wpp: usize,
        m: usize,
        k: usize,
    ) -> Result<Self, ContractError> {
        let np = num_panels(m);
        let nkb = k.div_ceil(SPARSE_KB);
        let expected_wpp = nkb.div_ceil(64);
        if wpp != expected_wpp {
            return Err(ContractError::MaskWordsPerPanel { expected: expected_wpp, got: wpp, nkb });
        }
        let expected = np * wpp;
        if words.len() != expected {
            return Err(ContractError::MaskLen { expected, got: words.len(), np });
        }
        Ok(Self { words, wpp, np })
    }
}

/// Validate a panel range plus its output sub-slice: `p0 <= p1 <= np`,
/// `crow0 == p0 * PACK_MR`, and `c_len` covering *exactly* the range's
/// rows.  Exactness is the disjointness proof: when the pool splits
/// `0..np` into consecutive ranges, equal-length sub-slices tile the
/// output with no gap and no overlap, so concurrent range sweeps never
/// alias.
pub fn check_range_output(
    m: usize,
    n: usize,
    p0: usize,
    p1: usize,
    crow0: usize,
    c_len: usize,
) -> Result<(), ContractError> {
    let np = num_panels(m);
    if p0 > p1 || p1 > np {
        return Err(ContractError::PanelRange { p0, p1, np });
    }
    let row0 = p0 * PACK_MR;
    if crow0 != row0 {
        return Err(ContractError::OutputRow0 { crow0, expected: row0 });
    }
    let rows = (p1 * PACK_MR).min(m).saturating_sub(row0);
    let expected = rows * n;
    if c_len != expected {
        return Err(ContractError::OutputLen { expected, got: c_len, rows, n });
    }
    Ok(())
}

/// Validate the epilogue against the row count: bias (if any) has one
/// entry per row, and the activation segment map divides `m` evenly
/// (the `act_for_row` indexing requirement).
pub fn check_epilogue(epi: &Epilogue<'_>, m: usize) -> Result<(), ContractError> {
    if let Some(bias) = epi.bias {
        if bias.len() != m {
            return Err(ContractError::BiasLen { expected: m, got: bias.len() });
        }
    }
    if !epi.acts.is_empty() && m % epi.acts.len() != 0 {
        return Err(ContractError::ActSegments { m, nacts: epi.acts.len() });
    }
    Ok(())
}

/// Validate that the requested kernel family exists on this target.
/// (Runtime feature availability — `avxvnni`, `dotprod` — is enforced
/// separately by the `detect_host()` gate in the `with_dispatch*`
/// constructors; this check only rules out tiers whose kernels are not
/// even compiled for the target architecture.)
pub fn check_simd(simd: Simd) -> Result<(), ContractError> {
    match simd {
        Simd::Avx2 if !cfg!(target_arch = "x86_64") => {
            Err(ContractError::SimdUnavailable { simd: "avx2" })
        }
        Simd::Vnni if !cfg!(target_arch = "x86_64") => {
            Err(ContractError::SimdUnavailable { simd: "vnni" })
        }
        Simd::Neon if !cfg!(target_arch = "aarch64") => {
            Err(ContractError::SimdUnavailable { simd: "neon" })
        }
        Simd::Sdot if !cfg!(target_arch = "aarch64") => {
            Err(ContractError::SimdUnavailable { simd: "sdot" })
        }
        _ => Ok(()),
    }
}

/// Validate the VNNI-only side buffers: `qshift` holds the `n * kp`
/// +128-shifted activation bytes and `corr` one `128 * sum(w)` entry
/// per packed row (`np * PACK_MR`, pad rows included).  Public so the
/// negative contract tests can hit each variant directly.
pub fn check_vnni_bufs(
    qshift: &[u8],
    corr: &[i32],
    m: usize,
    kp: usize,
    n: usize,
) -> Result<(), ContractError> {
    let expected = n * kp;
    if qshift.len() != expected {
        return Err(ContractError::ShiftLen { expected, got: qshift.len() });
    }
    let expected = num_panels(m) * PACK_MR;
    if corr.len() != expected {
        return Err(ContractError::CorrLen { expected, got: corr.len() });
    }
    Ok(())
}

/// Full precondition set of `kernels::matmul_range` (and therefore
/// `kernels::matmul`, which delegates with the full range).
#[allow(clippy::too_many_arguments)]
pub fn check_f32_dispatch(
    simd: Simd,
    panels: &[f32],
    c_len: usize,
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: &Epilogue<'_>,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) -> Result<(), ContractError> {
    check_simd(simd)?;
    PanelView::new(panels, m, k)?;
    FrameView::new(x, n, k)?;
    if let Some((words, wpp)) = pm_all {
        MaskView::new(words, wpp, m, k)?;
    }
    check_range_output(m, n, p0, p1, crow0, c_len)?;
    check_epilogue(epi, m)
}

/// Full precondition set of `kernels::matmul_q8q`.
#[allow(clippy::too_many_arguments)]
pub fn check_q8q_dispatch(
    simd: Simd,
    qpanels: &[i8],
    c32_len: usize,
    crow0: usize,
    xq: &[i8],
    qpair: &[i32],
    qshift: &[u8],
    corr: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) -> Result<(), ContractError> {
    check_simd(simd)?;
    match simd {
        // Quad tiers consume the k-quad-interleaved layout; a
        // pair-packed panel (kp == k rounded to even) fails QuadKp
        // here, which is the wrong-tier panel/dispatch mix guard.
        Simd::Vnni => {
            QPanelView::new_quad(qpanels, m, kp, VNNI_Q8_MAX_K, "q8q-vnni")?;
            check_vnni_bufs(qshift, corr, m, kp, n)?;
        }
        Simd::Sdot => {
            QPanelView::new_quad(qpanels, m, kp, Q8_MAX_K, "q8q")?;
        }
        _ => {
            QPanelView::new(qpanels, m, kp)?;
        }
    }
    QFrameView::new(xq, qpair, n, kp)?;
    if let Some((words, wpp)) = pm_all {
        MaskView::new(words, wpp, m, kp)?;
    }
    check_range_output(m, n, p0, p1, crow0, c32_len)
}

/// Full precondition set of `kernels::matmul_q4`.
#[allow(clippy::too_many_arguments)]
pub fn check_q4_dispatch(
    simd: Simd,
    q4panels: &[u8],
    c32_len: usize,
    crow0: usize,
    xq: &[i8],
    qpair: &[i32],
    qshift: &[u8],
    corr: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) -> Result<(), ContractError> {
    check_simd(simd)?;
    match simd {
        Simd::Vnni => {
            Q4PanelView::new_quad(q4panels, m, kp, VNNI_Q4_MAX_K, "q4-vnni")?;
            check_vnni_bufs(qshift, corr, m, kp, n)?;
        }
        Simd::Sdot => {
            Q4PanelView::new_quad(q4panels, m, kp, Q4_MAX_K, "q4")?;
        }
        _ => {
            Q4PanelView::new(q4panels, m, kp)?;
        }
    }
    QFrameView::new(xq, qpair, n, kp)?;
    if let Some((words, wpp)) = pm_all {
        MaskView::new(words, wpp, m, kp)?;
    }
    check_range_output(m, n, p0, p1, crow0, c32_len)
}

/// Shared geometry of every element-wise chain: gates are `[h, stride]`
/// row-major planes whose time window `off..off + t` is walked
/// sequentially, the output is a `[stride, h]` time-major plane, and
/// the carried state holds `h` entries.
#[allow(clippy::too_many_arguments)]
fn check_chain_geometry(
    simd: Simd,
    gate_lens: &[usize],
    h: usize,
    stride: usize,
    off: usize,
    t: usize,
    c_len: usize,
    out_len: usize,
) -> Result<(), ContractError> {
    check_simd(simd)?;
    if off + t > stride {
        return Err(ContractError::ChainWindow { off, t, stride });
    }
    let plane = h * stride;
    for &got in gate_lens {
        if got != plane {
            return Err(ContractError::GateLen { expected: plane, got, h, stride });
        }
    }
    if c_len != h {
        return Err(ContractError::StateLen { expected: h, got: c_len });
    }
    let expected = stride * h;
    if out_len != expected {
        return Err(ContractError::ChainOut { expected, got: out_len, stride, h });
    }
    Ok(())
}

/// Full precondition set of `engine::recurrence::sru_chain`: three gate
/// planes, the `[stride, d]` input frames the highway reads, and
/// `h <= d` for the highway column access.
#[allow(clippy::too_many_arguments)]
pub fn check_sru_chain(
    simd: Simd,
    gx_len: usize,
    gf_len: usize,
    gr_len: usize,
    h: usize,
    stride: usize,
    off: usize,
    t: usize,
    x_len: usize,
    d: usize,
    c_len: usize,
    out_len: usize,
) -> Result<(), ContractError> {
    check_chain_geometry(simd, &[gx_len, gf_len, gr_len], h, stride, off, t, c_len, out_len)?;
    let expected = stride * d;
    if x_len != expected {
        return Err(ContractError::FrameLen { expected, got: x_len, n: stride, k: d });
    }
    if h > d {
        return Err(ContractError::HighwayDim { h, d });
    }
    Ok(())
}

/// Full precondition set of `engine::recurrence::qrnn_chain` (the
/// fo-pool has no highway, so no input-frame condition).
#[allow(clippy::too_many_arguments)]
pub fn check_qrnn_chain(
    simd: Simd,
    gx_len: usize,
    gf_len: usize,
    go_len: usize,
    h: usize,
    stride: usize,
    off: usize,
    t: usize,
    c_len: usize,
    out_len: usize,
) -> Result<(), ContractError> {
    check_chain_geometry(simd, &[gx_len, gf_len, go_len], h, stride, off, t, c_len, out_len)
}

/// Full precondition set of `engine::recurrence::lstm_gate_fuse`: one
/// contiguous `[4h]` gate vector, `h`-length `c`/`h` state and output.
pub fn check_lstm_fuse(
    simd: Simd,
    g_len: usize,
    h: usize,
    c_len: usize,
    h_len: usize,
    out_len: usize,
) -> Result<(), ContractError> {
    check_simd(simd)?;
    if g_len != 4 * h {
        return Err(ContractError::GateLen { expected: 4 * h, got: g_len, h, stride: 4 });
    }
    if c_len != h {
        return Err(ContractError::StateLen { expected: h, got: c_len });
    }
    if h_len != h {
        return Err(ContractError::StateLen { expected: h, got: h_len });
    }
    if out_len != h {
        return Err(ContractError::StateLen { expected: h, got: out_len });
    }
    Ok(())
}

/// Full precondition set of `engine::recurrence::merge_sum`: forward,
/// backward and merged planes all hold `steps * h` entries.
pub fn check_merge(
    fwd_len: usize,
    bwd_len: usize,
    out_len: usize,
    steps: usize,
    h: usize,
) -> Result<(), ContractError> {
    let expected = steps * h;
    for got in [fwd_len, bwd_len] {
        if got != expected {
            return Err(ContractError::FrameLen { expected, got, n: steps, k: h });
        }
    }
    if out_len != expected {
        return Err(ContractError::ChainOut { expected, got: out_len, stride: steps, h });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_match_pack() {
        assert_eq!(Q8_MAX_K, crate::linalg::pack::Q8_MAX_K);
        assert_eq!(Q4_MAX_K, crate::linalg::pack::Q4_MAX_K);
        assert_eq!(VNNI_Q8_MAX_K, crate::linalg::pack::VNNI_Q8_MAX_K);
        assert_eq!(VNNI_Q4_MAX_K, crate::linalg::pack::VNNI_Q4_MAX_K);
        // The VNNI bounds are strictly tighter than the s8 x s8 ones —
        // the silent Vnni -> Avx2 demotion in `with_dispatch_q8q/q4`
        // relies on that ordering.
        assert!(VNNI_Q8_MAX_K < Q8_MAX_K);
        assert!(VNNI_Q4_MAX_K < Q4_MAX_K);
    }

    #[test]
    fn quad_views_enforce_quad_kp() {
        let (m, kp) = (16, 10);
        let q = vec![0i8; num_panels(m) * PACK_MR * kp];
        // kp = 10 is pair-legal but not quad-legal.
        assert!(QPanelView::new(&q, m, kp).is_ok());
        let err = QPanelView::new_quad(&q, m, kp, Q8_MAX_K, "q8q").unwrap_err();
        assert!(matches!(err, ContractError::QuadKp { kp: 10 }));
        let q4 = vec![0u8; num_panels(m) * (PACK_MR / 2) * kp];
        let err = Q4PanelView::new_quad(&q4, m, kp, Q4_MAX_K, "q4").unwrap_err();
        assert!(matches!(err, ContractError::QuadKp { kp: 10 }));
    }

    #[test]
    fn vnni_bufs_are_checked() {
        let (m, kp, n) = (16, 8, 3);
        let qshift = vec![128u8; n * kp];
        let corr = vec![0i32; num_panels(m) * PACK_MR];
        assert!(check_vnni_bufs(&qshift, &corr, m, kp, n).is_ok());
        let err = check_vnni_bufs(&qshift[1..], &corr, m, kp, n).unwrap_err();
        assert!(matches!(err, ContractError::ShiftLen { .. }));
        let err = check_vnni_bufs(&qshift, &corr[1..], m, kp, n).unwrap_err();
        assert!(matches!(err, ContractError::CorrLen { .. }));
    }

    #[test]
    fn happy_path_f32() {
        let (m, k, n) = (20, 7, 3);
        let np = num_panels(m);
        let panels = vec![0.0f32; np * PACK_MR * k];
        let x = vec![0.0f32; n * k];
        assert!(check_f32_dispatch(
            Simd::Portable,
            &panels,
            m * n,
            0,
            &x,
            m,
            k,
            n,
            &Epilogue::NONE,
            None,
            0,
            np
        )
        .is_ok());
    }

    #[test]
    fn range_disjointness_is_enforced() {
        // crow0 not on the p0 panel boundary aliases the prior range.
        let err = check_range_output(32, 4, 1, 2, 8, 16 * 4).unwrap_err();
        assert!(matches!(err, ContractError::OutputRow0 { .. }));
        // Oversized output overlaps the next range.
        let err = check_range_output(32, 4, 0, 1, 0, 17 * 4).unwrap_err();
        assert!(matches!(err, ContractError::OutputLen { .. }));
    }

    #[test]
    fn display_is_precise() {
        let e = ContractError::PanelLen { expected: 224, got: 200, np: 2, stride: 112 };
        let s = e.to_string();
        assert!(s.contains("224") && s.contains("200"), "{s}");
    }

    #[test]
    fn chain_geometry_is_enforced() {
        let (h, stride, d) = (8, 10, 12);
        let plane = h * stride;
        let ok = |off: usize, t: usize| {
            check_sru_chain(
                Simd::Portable,
                plane,
                plane,
                plane,
                h,
                stride,
                off,
                t,
                stride * d,
                d,
                h,
                stride * h,
            )
        };
        assert!(ok(0, stride).is_ok());
        assert!(ok(3, 7).is_ok());
        assert!(ok(4, 0).is_ok(), "zero-length segments are legal");
        // Window escapes the stride.
        let err = ok(4, 7).unwrap_err();
        assert!(matches!(err, ContractError::ChainWindow { off: 4, t: 7, stride: 10 }));
        // Short gate plane.
        let err = check_sru_chain(
            Simd::Portable,
            plane - 1,
            plane,
            plane,
            h,
            stride,
            0,
            stride,
            stride * d,
            d,
            h,
            stride * h,
        )
        .unwrap_err();
        assert!(matches!(err, ContractError::GateLen { .. }));
        // Highway needs h <= d.
        let err = check_sru_chain(
            Simd::Portable,
            plane,
            plane,
            plane,
            h,
            stride,
            0,
            stride,
            stride * 4,
            4,
            h,
            stride * h,
        )
        .unwrap_err();
        assert!(matches!(err, ContractError::HighwayDim { h: 8, d: 4 }));
        // QRNN shares the window/plane rules without the highway.
        assert!(check_qrnn_chain(
            Simd::Portable,
            plane,
            plane,
            plane,
            h,
            stride,
            2,
            8,
            h,
            stride * h
        )
        .is_ok());
        let err = check_qrnn_chain(
            Simd::Portable,
            plane,
            plane,
            plane,
            h,
            stride,
            0,
            stride,
            h - 1,
            stride * h,
        )
        .unwrap_err();
        assert!(matches!(err, ContractError::StateLen { .. }));
    }

    #[test]
    fn lstm_and_merge_shapes() {
        assert!(check_lstm_fuse(Simd::Portable, 32, 8, 8, 8, 8).is_ok());
        let err = check_lstm_fuse(Simd::Portable, 31, 8, 8, 8, 8).unwrap_err();
        assert!(matches!(err, ContractError::GateLen { .. }));
        let err = check_lstm_fuse(Simd::Portable, 32, 8, 7, 8, 8).unwrap_err();
        assert!(matches!(err, ContractError::StateLen { .. }));
        assert!(check_merge(40, 40, 40, 5, 8).is_ok());
        let err = check_merge(40, 39, 40, 5, 8).unwrap_err();
        assert!(matches!(err, ContractError::FrameLen { .. }));
        let err = check_merge(40, 40, 41, 5, 8).unwrap_err();
        assert!(matches!(err, ContractError::ChainOut { .. }));
    }
}
