//! Persistent worker pool for multicore execution (std-only, no rayon).
//!
//! The paper amortizes one DRAM read of the weights over `T` time steps;
//! on a multicore CPU the same weights can additionally be shared across
//! cores through the LLC, multiplying the arithmetic done per byte
//! streamed (the E-PUR weight-locality argument in software).  This pool
//! is how every hot path gets at those cores:
//!
//! * [`ThreadPool::run`] executes `count` index-addressed tasks across
//!   the workers plus the calling thread.  Idle workers *steal* the next
//!   task index from a shared atomic counter, so panels of very uneven
//!   cost (e.g. the zero-padded tail panel) cannot straggle a static
//!   partition.
//! * Determinism: the pool assigns *which thread* runs a task, never
//!   *what* the task computes — callers split work into disjoint output
//!   regions (row panels, pipeline stages), so results are bit-identical
//!   to serial execution regardless of scheduling.  This is asserted by
//!   `rust/tests/parallel_parity.rs`.
//! * Re-entrancy: `run` called from inside a worker task executes inline
//!   and serially ([`in_worker`]).  Wavefront layer tasks therefore run
//!   their GEMMs single-threaded instead of deadlocking the pool.
//! * Panics in tasks are caught, the remaining tasks still drain (so no
//!   caller or sibling deadlocks), and the panic is re-raised on the
//!   calling thread after the join.  The pool stays usable afterwards.
//!
//! One process-wide pool ([`current`]) is shared by all engines.  Its
//! size resolves as: explicit [`set_threads`] (the CLI's `--threads`) >
//! `MTSRNN_THREADS` env > `std::thread::available_parallelism()`.
//! `threads == 1` means no workers exist and every `run` is an inline
//! serial loop — the exact legacy single-threaded path.
//!
//! The claim/steal/remaining/condvar protocol below imports its
//! primitives from [`crate::sync`] so `RUSTFLAGS="--cfg loom"` can swap
//! them for the miniloom scheduler: `tests/loom_pool.rs` exhaustively
//! model-checks claim races, join-before-drain, panic-during-steal and
//! shutdown.  The process-global registry at the bottom stays on `std`
//! — it is not part of the modeled protocol.

// This module is on the crate's unsafe allowlist (see lib.rs and
// docs/UNSAFE.md): it owns the SendPtr escape hatch and the
// lifetime-erased job closure.
#![allow(unsafe_code)]

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::thread::JoinHandle;
use crate::sync::{Arc, Condvar, Mutex};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

/// Below this many multiply-adds a GEMM is not worth dispatching to the
/// pool: wake + join costs a few microseconds, which only pays for
/// itself once the kernel runs at least that long.
pub const PAR_MIN_WORK: usize = 1 << 14;

/// A raw pointer that may cross threads.  Used by callers of
/// [`ThreadPool::run`] to hand each task its *disjoint* slice of a
/// shared output buffer; the pool's join provides the happens-before
/// edge back to the caller.
#[derive(Debug, Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

// SAFETY: SendPtr is a plain address; the *callers* guarantee that
// concurrent tasks only touch disjoint regions behind it.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn get(self) -> *mut T {
        self.0
    }
}

/// One posted parallel job: an erased task closure plus the claim /
/// completion counters the workers share.
struct Job {
    /// Borrowed from the `run` caller; valid until `remaining == 0`,
    /// which `run` awaits before returning.
    func: *const (dyn Fn(usize) + Sync),
    /// Next unclaimed task index (the steal counter).
    next: AtomicUsize,
    /// Tasks not yet finished (claimed or not).
    remaining: AtomicUsize,
    count: usize,
    /// First task panic's payload, re-raised on the calling thread
    /// after the join so the original message survives multicore runs.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    gen: u64,
}

// SAFETY: `func` is only dereferenced for claimed task indices, all of
// which complete before `run` returns and drops the closure.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Slot {
    job: Option<Arc<Job>>,
    gen: u64,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers wait here for a new job generation.
    work_cv: Condvar,
    /// `run` waits here for `remaining == 0`.
    done_cv: Condvar,
}

/// Persistent worker pool; `threads - 1` parked worker threads (the
/// calling thread is always the `threads`-th participant).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

thread_local! {
    static IN_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// True while the current thread is executing a pool task.  Parallel
/// helpers consult this to run inline instead of re-entering the pool.
pub fn in_worker() -> bool {
    IN_WORKER.with(|c| c.get())
}

impl ThreadPool {
    /// Pool with `threads` total participants (min 1).  `threads - 1`
    /// worker threads are spawned; they park on a condvar between jobs.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                job: None,
                gen: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let sh = shared.clone();
                crate::sync::thread::Builder::new()
                    .name(format!("mtsrnn-w{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        Self {
            shared,
            workers,
            threads,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0..count)` across the workers + the calling thread and
    /// wait for all of them.  Tasks are claimed one index at a time from
    /// a shared counter (panel-level stealing).  Serial inline when the
    /// pool has one thread, there is one task, or the caller is itself a
    /// pool task (re-entrancy).  Panics in any task are re-raised here
    /// after every task has drained.
    pub fn run<F: Fn(usize) + Sync>(&self, count: usize, f: F) {
        self.run_dyn(count, &f)
    }

    fn run_dyn(&self, count: usize, f: &(dyn Fn(usize) + Sync)) {
        if count == 0 {
            return;
        }
        if self.threads <= 1 || count == 1 || in_worker() {
            for ti in 0..count {
                f(ti);
            }
            return;
        }
        // Erase the closure's borrow lifetime for storage in the job
        // header (the field's trait-object pointer defaults to
        // `'static`).
        // SAFETY: `run_dyn` does not return until `remaining == 0`, and
        // workers only dereference `func` for claimed task indices, so
        // the borrow outlives every use.  `tests/loom_pool.rs` model-
        // checks exactly this property (no claim after the join).
        let func: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let job = {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.gen += 1;
            let job = Arc::new(Job {
                func,
                next: AtomicUsize::new(0),
                remaining: AtomicUsize::new(count),
                count,
                panic_payload: Mutex::new(None),
                gen: slot.gen,
            });
            slot.job = Some(job.clone());
            self.shared.work_cv.notify_all();
            job
        };
        // The caller participates like any worker.
        run_tasks(&self.shared, &job);
        let mut slot = self.shared.slot.lock().unwrap();
        while job.remaining.load(Ordering::Acquire) != 0 {
            slot = self.shared.done_cv.wait(slot).unwrap();
        }
        // Clear the slot so late-waking workers don't rescan a dead job.
        if slot.job.as_ref().is_some_and(|j| Arc::ptr_eq(j, &job)) {
            slot.job = None;
        }
        drop(slot);
        if let Some(payload) = job.panic_payload.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen_gen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if let Some(j) = &slot.job {
                    if j.gen != seen_gen {
                        break j.clone();
                    }
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        seen_gen = job.gen;
        run_tasks(shared, &job);
    }
}

/// Claim and execute tasks until the job's counter is exhausted.
fn run_tasks(shared: &Shared, job: &Job) {
    IN_WORKER.with(|c| c.set(true));
    loop {
        let ti = job.next.fetch_add(1, Ordering::Relaxed);
        if ti >= job.count {
            break;
        }
        // SAFETY: `remaining > 0` (this claim is unfinished), so `run`
        // has not returned and the closure is still alive.
        let f = unsafe { &*job.func };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(ti))) {
            // Keep the FIRST payload (later ones are usually cascade).
            let mut slot = job.panic_payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        // AcqRel: publishes this task's writes to whoever observes 0.
        if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _guard = shared.slot.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
    IN_WORKER.with(|c| c.set(false));
}

// ---------------------------------------------------------------------
// Process-wide pool
// ---------------------------------------------------------------------

// Explicitly `std` (not `crate::sync`): statics need const
// constructors, and the global registry is not part of the
// loom-modeled protocol.
static GLOBAL: std::sync::Mutex<Option<Arc<ThreadPool>>> = std::sync::Mutex::new(None);

/// Lock-free snapshot of the process pool's size (0 = not yet built).
/// Hot paths consult this before deciding to parallelize, so a
/// single-threaded process never touches the `GLOBAL` mutex per GEMM.
static THREADS_HINT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

fn default_threads() -> usize {
    match std::env::var("MTSRNN_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!("warning: invalid MTSRNN_THREADS={v:?}, using available cores");
                available_cores()
            }
        },
        Err(_) => available_cores(),
    }
}

fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The process-wide pool (created on first use; see module docs for how
/// its size resolves).  Callers hold the returned `Arc` only for the
/// duration of one operation, so [`set_threads`] can swap the pool.
pub fn current() -> Arc<ThreadPool> {
    let mut g = GLOBAL.lock().unwrap();
    let pool = g
        .get_or_insert_with(|| Arc::new(ThreadPool::new(default_threads())))
        .clone();
    THREADS_HINT.store(pool.threads(), Ordering::Relaxed);
    pool
}

/// Replace the process-wide pool with one of `n` threads (the CLI's
/// `--threads`, and the benches' thread-scaling sweeps).  The old pool's
/// workers shut down once its last in-flight operation finishes.
pub fn set_threads(n: usize) {
    let n = n.max(1);
    let mut g = GLOBAL.lock().unwrap();
    if !g.as_ref().is_some_and(|p| p.threads() == n) {
        *g = Some(Arc::new(ThreadPool::new(n)));
    }
    THREADS_HINT.store(n, Ordering::Relaxed);
}

/// Thread count of the process-wide pool.
pub fn threads() -> usize {
    current().threads()
}

/// Cheap (lock-free) thread-count check for hot paths; builds the pool
/// on first call, then never locks again until `set_threads`.
pub fn threads_hint() -> usize {
    match THREADS_HINT.load(Ordering::Relaxed) {
        0 => current().threads(),
        n => n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_run_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), |ti| {
            hits[ti].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.run(10, |ti| {
            sum.fetch_add(ti, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn nested_run_executes_serially_inline() {
        let pool = ThreadPool::new(4);
        let count = AtomicUsize::new(0);
        pool.run(3, |_| {
            assert!(in_worker());
            // Re-entrant run must not deadlock — it runs inline.
            pool.run(5, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(!in_worker());
        assert_eq!(count.load(Ordering::Relaxed), 15);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, |ti| {
                if ti == 7 {
                    panic!("injected");
                }
            });
        }));
        assert!(r.is_err(), "task panic must reach the caller");
        // The pool is still functional afterwards.
        let ok = AtomicUsize::new(0);
        pool.run(8, |_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn drop_shuts_down_workers() {
        let pool = ThreadPool::new(4);
        pool.run(4, |_| {});
        drop(pool); // must not hang
    }

    #[test]
    fn global_pool_resizes() {
        set_threads(2);
        assert_eq!(threads(), 2);
        set_threads(1);
        assert_eq!(threads(), 1);
    }
}
