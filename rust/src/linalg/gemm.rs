//! Blocked single-threaded f32 GEMM/GEMV — the BLAS substitute.
//!
//! The shape class we care about is the paper's Eq. (4):
//!
//! ```text
//! C[M, N] = A[M, K] @ B[K, N]
//! ```
//!
//! with `A` a *weight* matrix (M = 3H/4H rows, K = D, both large) and `B`
//! the block of N = T input columns (N between 1 and 128).  The loop order
//! is chosen so each weight element is loaded **once** per block and used
//! N times from registers — the multi-time-step DRAM amortization the
//! paper builds on.  `B` and the 4-row `C` stripe stay cache-resident.
//!
//! `MR = 4` rows of `A` are processed together; the inner loop runs over
//! the contiguous `B` row so it auto-vectorizes (verified: produces packed
//! FMA under `-C target-cpu` defaults; see EXPERIMENTS.md §Perf).
//!
//! These row-major kernels are no longer the engine hot path: the packed
//! SIMD subsystem in [`crate::linalg::pack`] supersedes them there.  They
//! remain as (1) the loop structure the memsim traffic model mirrors,
//! (2) the baseline the construction-time crossover probe times against,
//! and (3) the `gemm_bt` fallback that probe can select at tiny `N`.

/// Rows of A processed per microkernel pass.
pub const MR: usize = 4;
/// K-blocking: a `MR x KC` A-stripe (64 KiB) stays L1/L2-resident while
/// its partial products accumulate.
pub const KC: usize = 256;

/// `c = a @ b`, overwriting `c`.  All row-major: a `[m,k]`, b `[k,n]`,
/// c `[m,n]`.
pub fn gemm(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(c.len(), m * n, "C size");
    c.fill(0.0);
    gemm_acc(c, a, b, m, k, n);
}

/// `c += a @ b` (no zeroing) — used for QRNN's two-term gate GEMM.
pub fn gemm_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(c.len(), m * n, "C size");
    if n == 1 {
        // Degenerate GEMV: per-row dot products are faster than the
        // broadcast kernel when there is only one column.
        gemv_acc(c, a, b, m, k);
        return;
    }
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        let mut i = 0;
        while i + MR <= m {
            kernel_4xn(
                c, a, b, i, k0, kc, n, k,
            );
            i += MR;
        }
        // Remainder rows.
        for r in i..m {
            let arow = &a[r * k + k0..r * k + k0 + kc];
            let crow = &mut c[r * n..(r + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[(k0 + kk) * n..(k0 + kk) * n + n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// Register-tile width (f32 columns held in accumulators per pass).
/// `MR x NR` = 4x16 f32 accumulators = 8 AVX2 ymm (or 4 AVX-512 zmm) —
/// fits the 16-register ymm file with room for the broadcast A values
/// and B loads.  (The packed kernels in `linalg::kernels` use taller
/// row-major-lane tiles instead: 16x6 for AVX2, 16x4 for NEON/portable.)
pub const NR: usize = 16;

/// 4 rows of A against the full N width for one K-stripe.
///
/// The N dimension is processed in `NR`-column register tiles: the
/// `[MR x NR]` accumulator array lives in SIMD registers across the
/// whole K-stripe (the compiler keeps fixed-size arrays register-
/// resident), so C traffic is one write per tile instead of one
/// read+write per `kk` — this doubled GFLOP/s over the slice-accumulate
/// version (see EXPERIMENTS.md §Perf).
#[inline]
fn kernel_4xn(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    i: usize,
    k0: usize,
    kc: usize,
    n: usize,
    lda: usize,
) {
    let a0 = &a[i * lda + k0..i * lda + k0 + kc];
    let a1 = &a[(i + 1) * lda + k0..(i + 1) * lda + k0 + kc];
    let a2 = &a[(i + 2) * lda + k0..(i + 2) * lda + k0 + kc];
    let a3 = &a[(i + 3) * lda + k0..(i + 3) * lda + k0 + kc];

    let mut j0 = 0;
    // Full NR-wide register tiles.
    while j0 + NR <= n {
        let mut acc = [[0f32; NR]; MR];
        for kk in 0..kc {
            let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + NR];
            let (v0, v1, v2, v3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            for j in 0..NR {
                let bv = brow[j];
                acc[0][j] += v0 * bv;
                acc[1][j] += v1 * bv;
                acc[2][j] += v2 * bv;
                acc[3][j] += v3 * bv;
            }
        }
        for (r, row_acc) in acc.iter().enumerate() {
            let crow = &mut c[(i + r) * n + j0..(i + r) * n + j0 + NR];
            for j in 0..NR {
                crow[j] += row_acc[j];
            }
        }
        j0 += NR;
    }
    // Remainder columns (n % NR): slice-accumulate tail.
    if j0 < n {
        let rem = n - j0;
        let mut acc = [[0f32; NR]; MR];
        for kk in 0..kc {
            let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + rem];
            let (v0, v1, v2, v3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            for j in 0..rem {
                let bv = brow[j];
                acc[0][j] += v0 * bv;
                acc[1][j] += v1 * bv;
                acc[2][j] += v2 * bv;
                acc[3][j] += v3 * bv;
            }
        }
        for (r, row_acc) in acc.iter().enumerate() {
            let crow = &mut c[(i + r) * n + j0..(i + r) * n + j0 + rem];
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv += row_acc[j];
            }
        }
    }
}

/// Column-count threshold below which `gemm_bt` (multi-dot) beats the
/// broadcast 4xN kernel: at tiny N the N-inner loop cannot vectorize.
pub const SMALL_N_CUTOFF: usize = 8;

/// `c[m,n] = a[m,k] @ bt[n,k]^T` — GEMM with the **right operand given
/// transposed** (each of the `n` columns is a contiguous `k`-vector).
///
/// This is the engines' fast path for small block sizes: the input block
/// is already time-major `[T, D]`, so no transpose is needed, and each
/// weight row is loaded once and dotted against all `n` frames (the
/// paper's "fetch one row of the weight matrix, use it for multiple time
/// steps" — literally).  Each dot uses the 8-lane unrolled kernel, so
/// small N keeps full K-vectorization (the 4xN kernel cannot).
pub fn gemm_bt(c: &mut [f32], a: &[f32], bt: &[f32], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    gemm_bt_acc(c, a, bt, m, k, n);
}

/// `c += a @ bt^T` (accumulating variant of [`gemm_bt`]).
pub fn gemm_bt_acc(c: &mut [f32], a: &[f32], bt: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(bt.len(), n * k, "Bt size");
    assert_eq!(c.len(), m * n, "C size");
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let crow = &mut c[r * n..(r + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv += dot(arow, &bt[j * k..(j + 1) * k]);
        }
    }
}

/// `y = a @ x` (single output column), overwriting y.  a `[m,k]`, x `[k]`.
pub fn gemv(y: &mut [f32], a: &[f32], x: &[f32], m: usize, k: usize) {
    assert_eq!(y.len(), m, "y size");
    y.fill(0.0);
    gemv_acc(y, a, x, m, k);
}

/// `y += a @ x`.  Row-wise dot products with 8-lane unrolling.
pub fn gemv_acc(y: &mut [f32], a: &[f32], x: &[f32], m: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(x.len(), k, "x size");
    assert_eq!(y.len(), m, "y size");
    for r in 0..m {
        let row = &a[r * k..(r + 1) * k];
        y[r] += dot(row, x);
    }
}

/// Unrolled dot product (8 partial sums hide FMA latency; autovectorizes).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let a8 = &a[i * 8..i * 8 + 8];
        let b8 = &b[i * 8..i * 8 + 8];
        for l in 0..8 {
            acc[l] += a8[l] * b8[l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Add a per-row bias to a `[m, n]` row-major matrix (gate epilogue).
pub fn add_row_bias(c: &mut [f32], bias: &[f32], m: usize, n: usize) {
    assert_eq!(c.len(), m * n);
    assert_eq!(bias.len(), m);
    for r in 0..m {
        let bv = bias[r];
        for v in &mut c[r * n..(r + 1) * n] {
            *v += bv;
        }
    }
}

/// Naive triple loop — correctness oracle for the blocked kernels.
pub fn gemm_naive(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f64;
            for kk in 0..k {
                s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
            }
            c[i * n + j] = s as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn check_gemm(m: usize, k: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c = vec![0.0; m * n];
        let mut want = vec![0.0; m * n];
        gemm(&mut c, &a, &b, m, k, n);
        gemm_naive(&mut want, &a, &b, m, k, n);
        let tol = 1e-3 * (k as f32).sqrt();
        for (i, (&g, &w)) in c.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= tol.max(1e-4),
                "({m},{k},{n}) idx {i}: got {g} want {w}"
            );
        }
    }

    #[test]
    fn gemm_matches_naive_small() {
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 2),
            (4, 8, 16),
            (5, 7, 3),
            (8, 512, 1),
            (17, 33, 9),
        ] {
            check_gemm(m, k, n, 42 + m as u64);
        }
    }

    #[test]
    fn gemm_matches_naive_paper_shapes() {
        // SRU small T=8: [1536, 512] x [512, 8]; KC boundary crossing.
        check_gemm(1536, 512, 8, 1);
        // Odd everything, > KC in K.
        check_gemm(37, 1037, 11, 2);
    }

    #[test]
    fn gemm_acc_accumulates() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (6, 9, 4);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c = vec![1.0; m * n];
        gemm_acc(&mut c, &a, &b, m, k, n);
        let mut want = vec![0.0; m * n];
        gemm_naive(&mut want, &a, &b, m, k, n);
        for (g, w) in c.iter().zip(&want) {
            assert!((g - (w + 1.0)).abs() < 1e-3);
        }
    }

    #[test]
    fn gemm_bt_matches_gemm() {
        let mut rng = Rng::new(31);
        for &(m, k, n) in &[(1, 1, 1), (17, 33, 2), (48, 512, 4), (64, 100, 8)] {
            let a = rand_vec(&mut rng, m * k);
            let bt = rand_vec(&mut rng, n * k);
            // b = bt^T
            let mut b = vec![0.0; k * n];
            for j in 0..n {
                for kk in 0..k {
                    b[kk * n + j] = bt[j * k + kk];
                }
            }
            let mut want = vec![0.0; m * n];
            gemm_naive(&mut want, &a, &b, m, k, n);
            let mut got = vec![0.0; m * n];
            gemm_bt(&mut got, &a, &bt, m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "({m},{k},{n}): {g} vs {w}");
            }
            // accumulate variant
            let mut acc = vec![1.0; m * n];
            gemm_bt_acc(&mut acc, &a, &bt, m, k, n);
            for (g, w) in acc.iter().zip(&want) {
                assert!((g - (w + 1.0)).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn gemv_matches_gemm_n1() {
        let mut rng = Rng::new(6);
        let (m, k) = (100, 257);
        let a = rand_vec(&mut rng, m * k);
        let x = rand_vec(&mut rng, k);
        let mut y = vec![0.0; m];
        gemv(&mut y, &a, &x, m, k);
        let mut want = vec![0.0; m];
        gemm_naive(&mut want, &a, &x, m, k, 1);
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for len in [0, 1, 7, 8, 9, 64, 65] {
            let a: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let b = vec![2.0f32; len];
            let want: f32 = a.iter().sum::<f32>() * 2.0;
            assert_eq!(dot(&a, &b), want, "len {len}");
        }
    }

    #[test]
    fn bias_broadcast() {
        let mut c = vec![0.0; 6];
        add_row_bias(&mut c, &[1.0, 2.0], 2, 3);
        assert_eq!(c, vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "B size")]
    fn shape_mismatch_panics() {
        let mut c = vec![0.0; 4];
        gemm(&mut c, &[0.0; 4], &[0.0; 5], 2, 2, 2);
    }
}
