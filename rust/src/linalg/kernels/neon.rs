//! aarch64 NEON microkernel: 16-row panels x 4-column register tile.
//!
//! Per k step: four 4-lane unit-stride panel loads plus one scalar frame
//! load per column feed `4 * NR` independent FMA chains via
//! `vfmaq_n_f32` — at `NR = 4` that is 16 q accumulators + 4 panel
//! registers out of the 32-register aarch64 SIMD file.  The embedded ARM
//! boards the paper targets (Tables 3/4/7/8) are exactly this path.

use core::arch::aarch64::{vdupq_n_f32, vfmaq_n_f32, vld1q_f32, vst1q_f32};

use super::store_tile;
use crate::linalg::pack::{Epilogue, PACK_MR};

/// Register-tile width (frame columns per microkernel pass).
pub(crate) const NR: usize = 4;

macro_rules! def_kern {
    ($name:ident, $nr:literal) => {
        /// # Safety
        /// Requires neon.  `panel` must hold `k * PACK_MR` floats and `x`
        /// must hold at least `(j0 + $nr) * k` floats.
        #[target_feature(enable = "neon")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const f32,
            x: *const f32,
            k: usize,
            j0: usize,
            tile: &mut [[f32; PACK_MR]; NR],
        ) {
            let zero = vdupq_n_f32(0.0);
            let mut acc = [[zero; 4]; $nr];
            let mut frames = [x; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                *f = x.add((j0 + jj) * k);
            }
            for kk in 0..k {
                let a0 = vld1q_f32(panel.add(kk * PACK_MR));
                let a1 = vld1q_f32(panel.add(kk * PACK_MR + 4));
                let a2 = vld1q_f32(panel.add(kk * PACK_MR + 8));
                let a3 = vld1q_f32(panel.add(kk * PACK_MR + 12));
                for jj in 0..$nr {
                    let b = *frames[jj].add(kk);
                    acc[jj][0] = vfmaq_n_f32(acc[jj][0], a0, b);
                    acc[jj][1] = vfmaq_n_f32(acc[jj][1], a1, b);
                    acc[jj][2] = vfmaq_n_f32(acc[jj][2], a2, b);
                    acc[jj][3] = vfmaq_n_f32(acc[jj][3], a3, b);
                }
            }
            for jj in 0..$nr {
                for l in 0..4 {
                    vst1q_f32(tile[jj].as_mut_ptr().add(4 * l), acc[jj][l]);
                }
            }
        }
    };
}

def_kern!(kern1, 1);
def_kern!(kern2, 2);
def_kern!(kern3, 3);
def_kern!(kern4, 4);

/// `c` covers rows `crow0..` of the output; `p0..p1` is the panel range
/// to compute (full sweep: `crow0 = 0`, `p0 = 0`, `p1 = ceil(m / MR)`).
///
/// # Safety
/// Requires neon (baseline on aarch64; verified by `detect()`).  Slice
/// sizes are checked by `PackedGemm::matmul`.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul(
    panels: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(panels.len(), m.div_ceil(PACK_MR) * PACK_MR * k);
    let mut tile = [[0f32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = panels[pi * PACK_MR * k..].as_ptr();
        let xp = x.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            match nr {
                4 => kern4(panel, xp, k, j0, &mut tile),
                3 => kern3(panel, xp, k, j0, &mut tile),
                2 => kern2(panel, xp, k, j0, &mut tile),
                _ => kern1(panel, xp, k, j0, &mut tile),
            }
            store_tile(c, crow0, &tile, j0, nr, pi * PACK_MR, m, n, acc, None, epi);
            j0 += nr;
        }
    }
}
