//! aarch64 NEON microkernel: 16-row panels x 4-column register tile.
//!
//! Per k step: four 4-lane unit-stride panel loads plus one scalar frame
//! load per column feed `4 * NR` independent FMA chains via
//! `vfmaq_n_f32` — at `NR = 4` that is 16 q accumulators + 4 panel
//! registers out of the 32-register aarch64 SIMD file.  The embedded ARM
//! boards the paper targets (Tables 3/4/7/8) are exactly this path.

// On the audited unsafe allowlist (see `tools/lint` and
// `docs/UNSAFE.md`).  Under `deny(unsafe_op_in_unsafe_fn)` the value
// intrinsics are safe inside these `#[target_feature]` functions; the
// `unsafe {}` blocks below mark exactly the raw-pointer operations,
// each with the bound that keeps it in range.  The bounds themselves
// are validated at the dispatch boundary by `linalg::contract`.
#![allow(unsafe_code)]

use core::arch::aarch64::{
    vdotq_s32, vdup_n_u16, vdupq_n_f32, vdupq_n_s32, vfmaq_n_f32, vget_high_s8, vget_low_s8,
    vld1_s8, vld1q_f32, vld1q_s8, vmull_s8, vpadalq_s16, vreinterpret_s8_u16,
    vreinterpretq_s8_s32, vshlq_n_s8, vshrq_n_s8, vst1q_f32, vst1q_s32, vzip1q_s8, vzip2q_s8,
};

use super::{kb_active, store_tile, store_tile_i32};
use crate::linalg::pack::{Epilogue, PACK_MR, SPARSE_KB};

/// Register-tile width (frame columns per microkernel pass).
pub(crate) const NR: usize = 4;

macro_rules! def_kern {
    ($name:ident, $nr:literal) => {
        /// # Safety
        /// Requires neon.  `panel` must hold `k * PACK_MR` floats and `x`
        /// must hold at least `(j0 + $nr) * k` floats.
        #[target_feature(enable = "neon")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const f32,
            x: *const f32,
            k: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[f32; PACK_MR]; NR],
        ) {
            let zero = vdupq_n_f32(0.0);
            let mut acc = [[zero; 4]; $nr];
            let mut frames = [x; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `x` holds `(j0 + $nr) * k`
                // floats, so frame `j0 + jj` starts in bounds.
                *f = unsafe { x.add((j0 + jj) * k) };
            }
            // K walks in SPARSE_KB chunks; skipping an all-zero block
            // leaves the surviving FMA chain identical to the dense
            // sweep, so sparse output is bitwise-equal to dense.
            let mut kb0 = 0usize;
            while kb0 < k {
                let ke = (kb0 + SPARSE_KB).min(k);
                if kb_active(pm, kb0 / SPARSE_KB) {
                    for kk in kb0..ke {
                        // SAFETY: kk < k and the panel holds
                        // `k * PACK_MR` floats, so all four 4-lane
                        // loads stay inside panel column kk.
                        let (a0, a1, a2, a3) = unsafe {
                            (
                                vld1q_f32(panel.add(kk * PACK_MR)),
                                vld1q_f32(panel.add(kk * PACK_MR + 4)),
                                vld1q_f32(panel.add(kk * PACK_MR + 8)),
                                vld1q_f32(panel.add(kk * PACK_MR + 12)),
                            )
                        };
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at a k-float
                            // frame and kk < k.
                            let b = unsafe { *frames[jj].add(kk) };
                            acc[jj][0] = vfmaq_n_f32(acc[jj][0], a0, b);
                            acc[jj][1] = vfmaq_n_f32(acc[jj][1], a1, b);
                            acc[jj][2] = vfmaq_n_f32(acc[jj][2], a2, b);
                            acc[jj][3] = vfmaq_n_f32(acc[jj][3], a3, b);
                        }
                    }
                }
                kb0 = ke;
            }
            for jj in 0..$nr {
                for l in 0..4 {
                    // SAFETY: tile[jj] is [f32; PACK_MR] = 16 floats;
                    // the four 4-lane stores cover elements 0..16.
                    unsafe { vst1q_f32(tile[jj].as_mut_ptr().add(4 * l), acc[jj][l]) };
                }
            }
        }
    };
}

def_kern!(kern1, 1);
def_kern!(kern2, 2);
def_kern!(kern3, 3);
def_kern!(kern4, 4);

/// `c` covers rows `crow0..` of the output; `p0..p1` is the panel range
/// to compute (full sweep: `crow0 = 0`, `p0 = 0`, `p1 = ceil(m / MR)`).
/// `pm_all` is the block-sparsity bitmap (`None` = dense).
///
/// # Safety
/// Requires neon (baseline on aarch64; verified by `detect()`).  The
/// caller must uphold the dispatch contract validated by
/// `contract::check_f32_dispatch`: `panels` holds
/// `ceil(m / PACK_MR) * PACK_MR * k` floats, `x` holds `n * k` floats,
/// `p0 <= p1 <= ceil(m / PACK_MR)`, `crow0 == p0 * PACK_MR`, `c` covers
/// exactly the range's rows, and any mask carries
/// `ceil(ceil(k / SPARSE_KB) / 64)` words per panel.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul(
    panels: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(panels.len(), m.div_ceil(PACK_MR) * PACK_MR * k);
    let mut tile = [[0f32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = panels[pi * PACK_MR * k..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let xp = x.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `k * PACK_MR` panel
            // (pi < p1 <= np and panels.len() == np * PACK_MR * k) and
            // `x` holds n * k floats with j0 + nr <= n — exactly each
            // kernel's documented requirement.
            unsafe {
                match nr {
                    4 => kern4(panel, xp, k, j0, pm, &mut tile),
                    3 => kern3(panel, xp, k, j0, pm, &mut tile),
                    2 => kern2(panel, xp, k, j0, pm, &mut tile),
                    _ => kern1(panel, xp, k, j0, pm, &mut tile),
                }
            }
            store_tile(c, crow0, &tile, j0, nr, pi * PACK_MR, m, n, acc, None, epi);
            j0 += nr;
        }
    }
}

macro_rules! def_kern_q8q {
    ($name:ident, $nr:literal) => {
        /// q8q integer microkernel (widening i16 dot): per k-pair, each
        /// 8-byte panel quarter (4 rows × 2 k, pair-interleaved) goes
        /// through one `vmull_s8` against the broadcast `[x0, x1]` i8
        /// pair and one `vpadalq_s16` pairwise add-accumulate into i32
        /// lanes — 8 MACs per multiply instruction vs 4 for f32
        /// `vfmaq`, and exact i32 arithmetic throughout (i8·i8 products
        /// fit i16, the pairwise sum widens to i32 before accumulation,
        /// so nothing ever saturates).  On `dotprod` hardware the
        /// dispatcher selects the `sdot` kernels below instead (4 MACs
        /// per instruction over k-quad panels); both tiers stay
        /// bit-compatible since i32 accumulation is order-independent.
        ///
        /// # Safety
        /// Requires neon.  `panel` must hold `kp * PACK_MR` bytes in the
        /// pair-interleaved q8q layout and `xq` at least
        /// `(j0 + $nr) * kp` bytes.
        #[target_feature(enable = "neon")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const i8,
            xq: *const i8,
            kp: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            let zero = vdupq_n_s32(0);
            let mut acc = [[zero; 4]; $nr];
            let mut frames = [xq; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `xq` holds
                // `(j0 + $nr) * kp` bytes, so frame `j0 + jj` starts
                // in bounds.
                *f = unsafe { xq.add((j0 + jj) * kp) };
            }
            // Pair loop chunked at SPARSE_KB / 2 pairs per block; for
            // odd k the pad pair shares the last real block's bit.
            let mut g0 = 0usize;
            while g0 < kp / 2 {
                let ge = (g0 + SPARSE_KB / 2).min(kp / 2);
                if kb_active(pm, g0 / (SPARSE_KB / 2)) {
                    for g in g0..ge {
                        // SAFETY: g < kp / 2 and the pair-interleaved
                        // panel holds kp * PACK_MR = (kp / 2) * 32
                        // bytes, so all four 8-byte loads stay inside
                        // pair-group g.
                        let (w0, w1, w2, w3) = unsafe {
                            (
                                vld1_s8(panel.add(g * 32)),
                                vld1_s8(panel.add(g * 32 + 8)),
                                vld1_s8(panel.add(g * 32 + 16)),
                                vld1_s8(panel.add(g * 32 + 24)),
                            )
                        };
                        for jj in 0..$nr {
                            // [x0, x1] repeated four times as an i8x8 vector.
                            // SAFETY: frames[jj] points at a kp-byte
                            // frame and 2 * g + 1 < kp; unaligned u16
                            // read of the adjacent byte pair.
                            let pair = unsafe {
                                (frames[jj].add(2 * g) as *const u16).read_unaligned()
                            };
                            let xp = vreinterpret_s8_u16(vdup_n_u16(pair));
                            acc[jj][0] = vpadalq_s16(acc[jj][0], vmull_s8(w0, xp));
                            acc[jj][1] = vpadalq_s16(acc[jj][1], vmull_s8(w1, xp));
                            acc[jj][2] = vpadalq_s16(acc[jj][2], vmull_s8(w2, xp));
                            acc[jj][3] = vpadalq_s16(acc[jj][3], vmull_s8(w3, xp));
                        }
                    }
                }
                g0 = ge;
            }
            for jj in 0..$nr {
                for l in 0..4 {
                    // SAFETY: tile[jj] is [i32; PACK_MR] = 16 lanes;
                    // the four 4-lane stores cover elements 0..16.
                    unsafe { vst1q_s32(tile[jj].as_mut_ptr().add(4 * l), acc[jj][l]) };
                }
            }
        }
    };
}

def_kern_q8q!(kq1, 1);
def_kern_q8q!(kq2, 2);
def_kern_q8q!(kq3, 3);
def_kern_q8q!(kq4, 4);

/// q8q integer GEMM over pair-interleaved panels; same panel-range /
/// sub-slice contract as [`matmul`], writing raw i32 accumulators.
///
/// # Safety
/// Requires neon (baseline on aarch64; verified by `detect()`).  The
/// caller must uphold the dispatch contract validated by
/// `contract::check_q8q_dispatch`: `qpanels` holds
/// `ceil(m / PACK_MR) * PACK_MR * kp` bytes with `kp` even and within
/// the i32-exactness bound, `xq` holds `n * kp` bytes,
/// `p0 <= p1 <= ceil(m / PACK_MR)`, `crow0 == p0 * PACK_MR`, and `c32`
/// covers exactly the range's rows.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q8q(
    qpanels: &[i8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(qpanels.len(), m.div_ceil(PACK_MR) * PACK_MR * kp);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = qpanels[pi * PACK_MR * kp..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let xp = xq.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `kp * PACK_MR`-byte q8q
            // panel and `xq` holds n * kp bytes with j0 + nr <= n —
            // exactly each kernel's documented requirement.
            unsafe {
                match nr {
                    4 => kq4(panel, xp, kp, j0, pm, &mut tile),
                    3 => kq3(panel, xp, kp, j0, pm, &mut tile),
                    2 => kq2(panel, xp, kp, j0, pm, &mut tile),
                    _ => kq1(panel, xp, kp, j0, pm, &mut tile),
                }
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}

macro_rules! def_kern_q4 {
    ($name:ident, $nr:literal) => {
        /// q4 integer microkernel: per k-pair, one 16-byte load carries
        /// **32 weights** (two signed nibbles per byte).  `vshl/vshr`
        /// by 4 sign-extend the low and high nibbles into two i8x16
        /// vectors whose byte `r` holds `w_{2g}` / `w_{2g+1}` for panel
        /// row `r`; `vzip1q/vzip2q` interleave them back into the
        /// pair-adjacent byte order the q8q quarters use, so the same
        /// `vmull_s8` + `vpadalq_s16` widening dot applies unchanged —
        /// half the weight bytes per k step, exact i32 accumulation
        /// (|w| <= 7, nothing saturates).
        ///
        /// # Safety
        /// Requires neon.  `panel` must hold `kp * PACK_MR / 2` bytes in
        /// the nibble-packed q4 layout and `xq` at least
        /// `(j0 + $nr) * kp` bytes.
        #[target_feature(enable = "neon")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const u8,
            xq: *const i8,
            kp: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            let zero = vdupq_n_s32(0);
            let mut acc = [[zero; 4]; $nr];
            let mut frames = [xq; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `xq` holds
                // `(j0 + $nr) * kp` bytes, so frame `j0 + jj` starts
                // in bounds.
                *f = unsafe { xq.add((j0 + jj) * kp) };
            }
            let mut g0 = 0usize;
            while g0 < kp / 2 {
                let ge = (g0 + SPARSE_KB / 2).min(kp / 2);
                if kb_active(pm, g0 / (SPARSE_KB / 2)) {
                    for g in g0..ge {
                        // SAFETY: g < kp / 2 and the nibble-packed
                        // panel holds (kp / 2) * 16 bytes, so the
                        // 16-byte load covers exactly pair-group g.
                        let raw = unsafe { vld1q_s8(panel.add(g * 16) as *const i8) };
                        let lo = vshrq_n_s8::<4>(vshlq_n_s8::<4>(raw));
                        let hi = vshrq_n_s8::<4>(raw);
                        // Rows 0-7 / 8-15, bytes pair-interleaved
                        // [w0_r, w1_r] exactly like the q8q layout.
                        let pa = vzip1q_s8(lo, hi);
                        let pb = vzip2q_s8(lo, hi);
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at a kp-byte
                            // frame and 2 * g + 1 < kp; unaligned u16
                            // read of the adjacent byte pair.
                            let pair = unsafe {
                                (frames[jj].add(2 * g) as *const u16).read_unaligned()
                            };
                            let xp = vreinterpret_s8_u16(vdup_n_u16(pair));
                            acc[jj][0] = vpadalq_s16(acc[jj][0], vmull_s8(vget_low_s8(pa), xp));
                            acc[jj][1] = vpadalq_s16(acc[jj][1], vmull_s8(vget_high_s8(pa), xp));
                            acc[jj][2] = vpadalq_s16(acc[jj][2], vmull_s8(vget_low_s8(pb), xp));
                            acc[jj][3] = vpadalq_s16(acc[jj][3], vmull_s8(vget_high_s8(pb), xp));
                        }
                    }
                }
                g0 = ge;
            }
            for jj in 0..$nr {
                for l in 0..4 {
                    // SAFETY: tile[jj] is [i32; PACK_MR] = 16 lanes;
                    // the four 4-lane stores cover elements 0..16.
                    unsafe { vst1q_s32(tile[jj].as_mut_ptr().add(4 * l), acc[jj][l]) };
                }
            }
        }
    };
}

def_kern_q4!(k41, 1);
def_kern_q4!(k42, 2);
def_kern_q4!(k43, 3);
def_kern_q4!(k44, 4);

/// q4 integer GEMM over nibble-packed panels; same panel-range /
/// sub-slice contract as [`matmul`], writing raw i32 accumulators.
///
/// # Safety
/// Requires neon (baseline on aarch64; verified by `detect()`).  The
/// caller must uphold the dispatch contract validated by
/// `contract::check_q4_dispatch`: `q4panels` holds
/// `ceil(m / PACK_MR) * (PACK_MR / 2) * kp` bytes with `kp` even and
/// within the q4 i32-exactness bound, `xq` holds `n * kp` bytes,
/// `p0 <= p1 <= ceil(m / PACK_MR)`, `crow0 == p0 * PACK_MR`, and `c32`
/// covers exactly the range's rows.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q4(
    q4panels: &[u8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(q4panels.len(), m.div_ceil(PACK_MR) * (PACK_MR / 2) * kp);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = q4panels[pi * (PACK_MR / 2) * kp..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let xp = xq.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `(kp / 2) * 16`-byte q4
            // panel and `xq` holds n * kp bytes with j0 + nr <= n —
            // exactly each kernel's documented requirement.
            unsafe {
                match nr {
                    4 => k44(panel, xp, kp, j0, pm, &mut tile),
                    3 => k43(panel, xp, kp, j0, pm, &mut tile),
                    2 => k42(panel, xp, kp, j0, pm, &mut tile),
                    _ => k41(panel, xp, kp, j0, pm, &mut tile),
                }
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}

macro_rules! def_kern_q8q_sdot {
    ($name:ident, $nr:literal) => {
        /// q8q `sdot` microkernel: per k-quad `g` (`kk = 4g`), each
        /// 16-byte quarter of the 64-byte quad group (4 rows x 4 k,
        /// row-major quads; i32 lane `l` = row `4q + l`) takes one
        /// `vdotq_s32` against the broadcast `[x_{4g} .. x_{4g+3}]` i8
        /// quad — **16 MACs per instruction**, twice the widening
        /// `vmull_s8` + `vpadalq_s16` rate, natively s8 x s8 (no zero
        /// point, no correction term) and exact i32 throughout, so the
        /// accumulators are bit-identical to every other family.
        ///
        /// # Safety
        /// Requires neon+dotprod.  `panel` must hold `kp * PACK_MR`
        /// bytes in the quad-interleaved q8q layout and `xq` at least
        /// `(j0 + $nr) * kp` bytes.
        #[target_feature(enable = "neon,dotprod")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const i8,
            xq: *const i8,
            kp: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            let zero = vdupq_n_s32(0);
            let mut acc = [[zero; 4]; $nr];
            let mut frames = [xq; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `xq` holds
                // `(j0 + $nr) * kp` bytes, so frame `j0 + jj` starts
                // in bounds.
                *f = unsafe { xq.add((j0 + jj) * kp) };
            }
            // Quad loop chunked at SPARSE_KB / 4 quads per sparse
            // block; skipping is exact (i32), so results stay
            // bit-identical to the dense sweep.
            let mut g0 = 0usize;
            while g0 < kp / 4 {
                let ge = (g0 + SPARSE_KB / 4).min(kp / 4);
                if kb_active(pm, g0 / (SPARSE_KB / 4)) {
                    for g in g0..ge {
                        // SAFETY: g < kp / 4 and the quad-interleaved
                        // panel holds kp * PACK_MR = (kp / 4) * 64
                        // bytes, so all four 16-byte loads stay inside
                        // quad-group g.
                        let (w0, w1, w2, w3) = unsafe {
                            (
                                vld1q_s8(panel.add(g * 64)),
                                vld1q_s8(panel.add(g * 64 + 16)),
                                vld1q_s8(panel.add(g * 64 + 32)),
                                vld1q_s8(panel.add(g * 64 + 48)),
                            )
                        };
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at a kp-byte
                            // frame and 4 * g + 3 < kp; unaligned i32
                            // read of the adjacent byte quad.
                            let quad = unsafe {
                                (frames[jj].add(4 * g) as *const i32).read_unaligned()
                            };
                            let xp = vreinterpretq_s8_s32(vdupq_n_s32(quad));
                            acc[jj][0] = vdotq_s32(acc[jj][0], w0, xp);
                            acc[jj][1] = vdotq_s32(acc[jj][1], w1, xp);
                            acc[jj][2] = vdotq_s32(acc[jj][2], w2, xp);
                            acc[jj][3] = vdotq_s32(acc[jj][3], w3, xp);
                        }
                    }
                }
                g0 = ge;
            }
            for jj in 0..$nr {
                for l in 0..4 {
                    // SAFETY: tile[jj] is [i32; PACK_MR] = 16 lanes;
                    // the four 4-lane stores cover elements 0..16.
                    unsafe { vst1q_s32(tile[jj].as_mut_ptr().add(4 * l), acc[jj][l]) };
                }
            }
        }
    };
}

def_kern_q8q_sdot!(ks1, 1);
def_kern_q8q_sdot!(ks2, 2);
def_kern_q8q_sdot!(ks3, 3);
def_kern_q8q_sdot!(ks4, 4);

/// q8q integer GEMM over quad-interleaved panels via `sdot`; same
/// panel-range / sub-slice contract as [`matmul`], writing raw i32
/// accumulators.
///
/// # Safety
/// Requires neon+dotprod (guaranteed by the `detect_host()` gate behind
/// the dispatcher).  The caller must uphold the dispatch contract
/// validated by `contract::check_q8q_dispatch` at the Sdot tier:
/// `qpanels` holds `ceil(m / PACK_MR) * PACK_MR * kp` bytes with
/// `kp % 4 == 0` and within the i32-exactness bound, `xq` holds
/// `n * kp` bytes, `p0 <= p1 <= ceil(m / PACK_MR)`,
/// `crow0 == p0 * PACK_MR`, and `c32` covers exactly the range's rows.
#[target_feature(enable = "neon,dotprod")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q8q_sdot(
    qpanels: &[i8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(qpanels.len(), m.div_ceil(PACK_MR) * PACK_MR * kp);
    debug_assert_eq!(kp % 4, 0);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = qpanels[pi * PACK_MR * kp..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let xp = xq.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `kp * PACK_MR`-byte quad
            // panel and `xq` holds n * kp bytes with j0 + nr <= n —
            // exactly each kernel's documented requirement.
            unsafe {
                match nr {
                    4 => ks4(panel, xp, kp, j0, pm, &mut tile),
                    3 => ks3(panel, xp, kp, j0, pm, &mut tile),
                    2 => ks2(panel, xp, kp, j0, pm, &mut tile),
                    _ => ks1(panel, xp, kp, j0, pm, &mut tile),
                }
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}

macro_rules! def_kern_q4_sdot {
    ($name:ident, $nr:literal) => {
        /// q4 `sdot` microkernel: per k-quad, two 16-byte loads carry
        /// **64 weights** (two signed nibbles per byte).  `vshl/vshr`
        /// by 4 sign-extend the low and high nibbles, then
        /// `vzip1q/vzip2q` rebuild row-major quads — the sdot group
        /// layout (`SDOT_Q4_GRP_BASE`) stores row quarters sequentially
        /// so the zip outputs are exactly the four 4-row weight vectors
        /// `vdotq_s32` wants, with no extra shuffle.  Same 16 MACs per
        /// dot instruction as the q8q sdot kernel at half the weight
        /// bytes, exact i32 throughout.
        ///
        /// # Safety
        /// Requires neon+dotprod.  `panel` must hold `kp * PACK_MR / 2`
        /// bytes in the sdot nibble-quad layout and `xq` at least
        /// `(j0 + $nr) * kp` bytes.
        #[target_feature(enable = "neon,dotprod")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const u8,
            xq: *const i8,
            kp: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            let zero = vdupq_n_s32(0);
            let mut acc = [[zero; 4]; $nr];
            let mut frames = [xq; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `xq` holds
                // `(j0 + $nr) * kp` bytes, so frame `j0 + jj` starts
                // in bounds.
                *f = unsafe { xq.add((j0 + jj) * kp) };
            }
            let mut g0 = 0usize;
            while g0 < kp / 4 {
                let ge = (g0 + SPARSE_KB / 4).min(kp / 4);
                if kb_active(pm, g0 / (SPARSE_KB / 4)) {
                    for g in g0..ge {
                        // SAFETY: g < kp / 4 and the nibble-quad panel
                        // holds (kp / 4) * 32 bytes, so both 16-byte
                        // loads stay inside quad-group g.
                        let (raw0, raw1) = unsafe {
                            (
                                vld1q_s8(panel.add(g * 32) as *const i8),
                                vld1q_s8(panel.add(g * 32 + 16) as *const i8),
                            )
                        };
                        let lo0 = vshrq_n_s8::<4>(vshlq_n_s8::<4>(raw0));
                        let hi0 = vshrq_n_s8::<4>(raw0);
                        let lo1 = vshrq_n_s8::<4>(vshlq_n_s8::<4>(raw1));
                        let hi1 = vshrq_n_s8::<4>(raw1);
                        // Zip restores [w0, w1, w2, w3] per row: rows
                        // 0-3 / 4-7 from the first half, 8-11 / 12-15
                        // from the second.
                        let w0 = vzip1q_s8(lo0, hi0);
                        let w1 = vzip2q_s8(lo0, hi0);
                        let w2 = vzip1q_s8(lo1, hi1);
                        let w3 = vzip2q_s8(lo1, hi1);
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at a kp-byte
                            // frame and 4 * g + 3 < kp; unaligned i32
                            // read of the adjacent byte quad.
                            let quad = unsafe {
                                (frames[jj].add(4 * g) as *const i32).read_unaligned()
                            };
                            let xp = vreinterpretq_s8_s32(vdupq_n_s32(quad));
                            acc[jj][0] = vdotq_s32(acc[jj][0], w0, xp);
                            acc[jj][1] = vdotq_s32(acc[jj][1], w1, xp);
                            acc[jj][2] = vdotq_s32(acc[jj][2], w2, xp);
                            acc[jj][3] = vdotq_s32(acc[jj][3], w3, xp);
                        }
                    }
                }
                g0 = ge;
            }
            for jj in 0..$nr {
                for l in 0..4 {
                    // SAFETY: tile[jj] is [i32; PACK_MR] = 16 lanes;
                    // the four 4-lane stores cover elements 0..16.
                    unsafe { vst1q_s32(tile[jj].as_mut_ptr().add(4 * l), acc[jj][l]) };
                }
            }
        }
    };
}

def_kern_q4_sdot!(ks41, 1);
def_kern_q4_sdot!(ks42, 2);
def_kern_q4_sdot!(ks43, 3);
def_kern_q4_sdot!(ks44, 4);

/// q4 integer GEMM over sdot nibble-quad panels; same panel-range /
/// sub-slice contract as [`matmul`], writing raw i32 accumulators.
///
/// # Safety
/// Requires neon+dotprod (guaranteed by the `detect_host()` gate behind
/// the dispatcher).  The caller must uphold the dispatch contract
/// validated by `contract::check_q4_dispatch` at the Sdot tier:
/// `q4panels` holds `ceil(m / PACK_MR) * (PACK_MR / 2) * kp` bytes with
/// `kp % 4 == 0` and within the q4 i32-exactness bound, `xq` holds
/// `n * kp` bytes, `p0 <= p1 <= ceil(m / PACK_MR)`,
/// `crow0 == p0 * PACK_MR`, and `c32` covers exactly the range's rows.
#[target_feature(enable = "neon,dotprod")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q4_sdot(
    q4panels: &[u8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(q4panels.len(), m.div_ceil(PACK_MR) * (PACK_MR / 2) * kp);
    debug_assert_eq!(kp % 4, 0);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = q4panels[pi * (PACK_MR / 2) * kp..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let xp = xq.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `(kp / 4) * 32`-byte
            // nibble-quad panel and `xq` holds n * kp bytes with
            // j0 + nr <= n — exactly each kernel's documented
            // requirement.
            unsafe {
                match nr {
                    4 => ks44(panel, xp, kp, j0, pm, &mut tile),
                    3 => ks43(panel, xp, kp, j0, pm, &mut tile),
                    2 => ks42(panel, xp, kp, j0, pm, &mut tile),
                    _ => ks41(panel, xp, kp, j0, pm, &mut tile),
                }
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}
