//! Portable packed-panel microkernel (16x4 tile) — the fallback path and
//! the correctness oracle for the intrinsic kernels.
//!
//! The inner row loop runs over `PACK_MR` contiguous panel elements with
//! a broadcast multiplier, the exact shape LLVM's autovectorizer turns
//! into packed FMA on any SIMD ISA the target baseline provides.  Also
//! hosts the int8 variant used by the quantized engine (dequantization
//! happens in registers; the per-row scale is fused into the store).

use super::store_tile;
use crate::linalg::pack::{Epilogue, PACK_MR};

/// Register-tile width (frame columns per microkernel pass).
pub(crate) const NR: usize = 4;

/// `c` covers rows `crow0..` of the output; `p0..p1` is the panel range
/// to compute (full sweep: `crow0 = 0`, `p0 = 0`, `p1 = ceil(m / MR)`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul(
    panels: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    p0: usize,
    p1: usize,
) {
    let mut tile = [[0f32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = &panels[pi * PACK_MR * k..(pi + 1) * PACK_MR * k];
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            match nr {
                4 => kern::<4>(panel, x, k, j0, &mut tile),
                3 => kern::<3>(panel, x, k, j0, &mut tile),
                2 => kern::<2>(panel, x, k, j0, &mut tile),
                _ => kern::<1>(panel, x, k, j0, &mut tile),
            }
            store_tile(c, crow0, &tile, j0, nr, pi * PACK_MR, m, n, acc, None, epi);
            j0 += nr;
        }
    }
}

fn kern<const NR2: usize>(
    panel: &[f32],
    x: &[f32],
    k: usize,
    j0: usize,
    tile: &mut [[f32; PACK_MR]; NR],
) {
    let mut acc = [[0f32; PACK_MR]; NR2];
    for kk in 0..k {
        let a = &panel[kk * PACK_MR..(kk + 1) * PACK_MR];
        for (jj, accj) in acc.iter_mut().enumerate() {
            let bv = x[(j0 + jj) * k + kk];
            for (dst, &av) in accj.iter_mut().zip(a) {
                *dst += av * bv;
            }
        }
    }
    tile[..NR2].copy_from_slice(&acc);
}

/// Int8 panels: identical tiling, with the `i8 -> f32` widen performed in
/// registers (weight bytes stream at 1/4 the f32 DRAM traffic).  Same
/// panel-range contract as [`matmul`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_quant(
    panels: &[i8],
    scales: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    p0: usize,
    p1: usize,
) {
    let mut tile = [[0f32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = &panels[pi * PACK_MR * k..(pi + 1) * PACK_MR * k];
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            match nr {
                4 => kern_q::<4>(panel, x, k, j0, &mut tile),
                3 => kern_q::<3>(panel, x, k, j0, &mut tile),
                2 => kern_q::<2>(panel, x, k, j0, &mut tile),
                _ => kern_q::<1>(panel, x, k, j0, &mut tile),
            }
            store_tile(c, crow0, &tile, j0, nr, pi * PACK_MR, m, n, acc, Some(scales), epi);
            j0 += nr;
        }
    }
}

fn kern_q<const NR2: usize>(
    panel: &[i8],
    x: &[f32],
    k: usize,
    j0: usize,
    tile: &mut [[f32; PACK_MR]; NR],
) {
    let mut acc = [[0f32; PACK_MR]; NR2];
    for kk in 0..k {
        let a = &panel[kk * PACK_MR..(kk + 1) * PACK_MR];
        for (jj, accj) in acc.iter_mut().enumerate() {
            let bv = x[(j0 + jj) * k + kk];
            for (dst, &av) in accj.iter_mut().zip(a) {
                *dst += f32::from(av) * bv;
            }
        }
    }
    tile[..NR2].copy_from_slice(&acc);
}

/// q8q integer kernel over the *pair-interleaved* i8 panel layout (see
/// `pack::pack_panels_q8q`): pure i32 multiply-accumulate, one column at
/// a time — the reference the intrinsic kernels must match **bit for
/// bit** (exact integer arithmetic makes the accumulation order
/// irrelevant, so each family is free to tile differently).
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_q8q(
    qpanels: &[i8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    m: usize,
    kp: usize,
    n: usize,
    p0: usize,
    p1: usize,
) {
    for pi in p0..p1 {
        let panel = &qpanels[pi * PACK_MR * kp..(pi + 1) * PACK_MR * kp];
        let row0 = pi * PACK_MR;
        let rows = PACK_MR.min(m - row0);
        for j in 0..n {
            let frame = &xq[j * kp..(j + 1) * kp];
            let mut acc = [0i32; PACK_MR];
            for g in 0..kp / 2 {
                let grp = &panel[g * 32..(g + 1) * 32];
                let x0 = i32::from(frame[2 * g]);
                let x1 = i32::from(frame[2 * g + 1]);
                for half in 0..2 {
                    for ri in 0..8 {
                        let w0 = i32::from(grp[half * 16 + ri * 2]);
                        let w1 = i32::from(grp[half * 16 + ri * 2 + 1]);
                        acc[half * 8 + ri] += w0 * x0 + w1 * x1;
                    }
                }
            }
            for (rl, &av) in acc.iter().enumerate().take(rows) {
                c32[(row0 - crow0 + rl) * n + j] = av;
            }
        }
    }
}
