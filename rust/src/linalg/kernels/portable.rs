//! Portable packed-panel microkernel (16x4 tile) — the fallback path and
//! the correctness oracle for the intrinsic kernels.
//!
//! The inner row loop runs over `PACK_MR` contiguous panel elements with
//! a broadcast multiplier, the exact shape LLVM's autovectorizer turns
//! into packed FMA on any SIMD ISA the target baseline provides.  Also
//! hosts the int8 variant used by the quantized engine (dequantization
//! happens in registers; the per-row scale is fused into the store).

use super::{kb_active, store_tile};
use crate::linalg::pack::{Epilogue, PACK_MR, SPARSE_KB};

/// Register-tile width (frame columns per microkernel pass).
pub(crate) const NR: usize = 4;

/// `c` covers rows `crow0..` of the output; `p0..p1` is the panel range
/// to compute (full sweep: `crow0 = 0`, `p0 = 0`, `p1 = ceil(m / MR)`).
/// `pm_all` is the block-sparsity bitmap (`None` = dense); each panel's
/// mask words ride next to its pointer into the kernel.
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul(
    panels: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    let mut tile = [[0f32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = &panels[pi * PACK_MR * k..(pi + 1) * PACK_MR * k];
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            match nr {
                4 => kern::<4>(panel, x, k, j0, pm, &mut tile),
                3 => kern::<3>(panel, x, k, j0, pm, &mut tile),
                2 => kern::<2>(panel, x, k, j0, pm, &mut tile),
                _ => kern::<1>(panel, x, k, j0, pm, &mut tile),
            }
            store_tile(c, crow0, &tile, j0, nr, pi * PACK_MR, m, n, acc, None, epi);
            j0 += nr;
        }
    }
}

fn kern<const NR2: usize>(
    panel: &[f32],
    x: &[f32],
    k: usize,
    j0: usize,
    pm: Option<&[u64]>,
    tile: &mut [[f32; PACK_MR]; NR],
) {
    let mut acc = [[0f32; PACK_MR]; NR2];
    // K walks in SPARSE_KB chunks; an inactive block's weights are all
    // exactly zero, so skipping its k-range changes no accumulator (the
    // in-order chunking keeps the surviving FMA chain identical to the
    // dense sweep — bitwise, not just tolerably).
    let mut kb0 = 0;
    while kb0 < k {
        let ke = (kb0 + SPARSE_KB).min(k);
        if kb_active(pm, kb0 / SPARSE_KB) {
            for kk in kb0..ke {
                let a = &panel[kk * PACK_MR..(kk + 1) * PACK_MR];
                for (jj, accj) in acc.iter_mut().enumerate() {
                    let bv = x[(j0 + jj) * k + kk];
                    for (dst, &av) in accj.iter_mut().zip(a) {
                        *dst += av * bv;
                    }
                }
            }
        }
        kb0 = ke;
    }
    tile[..NR2].copy_from_slice(&acc);
}

/// Int8 panels: identical tiling, with the `i8 -> f32` widen performed in
/// registers (weight bytes stream at 1/4 the f32 DRAM traffic).  Same
/// panel-range contract as [`matmul`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_quant(
    panels: &[i8],
    scales: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    let mut tile = [[0f32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = &panels[pi * PACK_MR * k..(pi + 1) * PACK_MR * k];
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            match nr {
                4 => kern_q::<4>(panel, x, k, j0, pm, &mut tile),
                3 => kern_q::<3>(panel, x, k, j0, pm, &mut tile),
                2 => kern_q::<2>(panel, x, k, j0, pm, &mut tile),
                _ => kern_q::<1>(panel, x, k, j0, pm, &mut tile),
            }
            store_tile(c, crow0, &tile, j0, nr, pi * PACK_MR, m, n, acc, Some(scales), epi);
            j0 += nr;
        }
    }
}

fn kern_q<const NR2: usize>(
    panel: &[i8],
    x: &[f32],
    k: usize,
    j0: usize,
    pm: Option<&[u64]>,
    tile: &mut [[f32; PACK_MR]; NR],
) {
    let mut acc = [[0f32; PACK_MR]; NR2];
    let mut kb0 = 0;
    while kb0 < k {
        let ke = (kb0 + SPARSE_KB).min(k);
        if kb_active(pm, kb0 / SPARSE_KB) {
            for kk in kb0..ke {
                let a = &panel[kk * PACK_MR..(kk + 1) * PACK_MR];
                for (jj, accj) in acc.iter_mut().enumerate() {
                    let bv = x[(j0 + jj) * k + kk];
                    for (dst, &av) in accj.iter_mut().zip(a) {
                        *dst += f32::from(av) * bv;
                    }
                }
            }
        }
        kb0 = ke;
    }
    tile[..NR2].copy_from_slice(&acc);
}

/// q8q integer kernel over the *pair-interleaved* i8 panel layout (see
/// `pack::pack_panels_q8q`): pure i32 multiply-accumulate, one column at
/// a time — the reference the intrinsic kernels must match **bit for
/// bit** (exact integer arithmetic makes the accumulation order
/// irrelevant, so each family is free to tile differently).
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_q8q(
    qpanels: &[i8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    for pi in p0..p1 {
        let panel = &qpanels[pi * PACK_MR * kp..(pi + 1) * PACK_MR * kp];
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let row0 = pi * PACK_MR;
        let rows = PACK_MR.min(m - row0);
        for j in 0..n {
            let frame = &xq[j * kp..(j + 1) * kp];
            let mut acc = [0i32; PACK_MR];
            // Pair loop chunked at SPARSE_KB / 2 pairs per block; for
            // odd k the pad pair shares the last real block's bit.
            let mut g0 = 0;
            while g0 < kp / 2 {
                let ge = (g0 + SPARSE_KB / 2).min(kp / 2);
                if kb_active(pm, g0 / (SPARSE_KB / 2)) {
                    for g in g0..ge {
                        let grp = &panel[g * 32..(g + 1) * 32];
                        let x0 = i32::from(frame[2 * g]);
                        let x1 = i32::from(frame[2 * g + 1]);
                        for half in 0..2 {
                            for ri in 0..8 {
                                let w0 = i32::from(grp[half * 16 + ri * 2]);
                                let w1 = i32::from(grp[half * 16 + ri * 2 + 1]);
                                acc[half * 8 + ri] += w0 * x0 + w1 * x1;
                            }
                        }
                    }
                }
                g0 = ge;
            }
            for (rl, &av) in acc.iter().enumerate().take(rows) {
                c32[(row0 - crow0 + rl) * n + j] = av;
            }
        }
    }
}

/// q4 integer kernel over the *nibble-packed* panel layout (see
/// `pack::pack_panels_q4`): per k-pair group, byte `r` splits into two
/// sign-extended nibbles in plain scalar code — the reference the
/// intrinsic q4 kernels must match **bit for bit** (exact i32
/// arithmetic; |w| <= 7, |x| <= 127 never overflows).
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_q4(
    q4panels: &[u8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    for pi in p0..p1 {
        let panel = &q4panels[pi * (PACK_MR / 2) * kp..(pi + 1) * (PACK_MR / 2) * kp];
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let row0 = pi * PACK_MR;
        let rows = PACK_MR.min(m - row0);
        for j in 0..n {
            let frame = &xq[j * kp..(j + 1) * kp];
            let mut acc = [0i32; PACK_MR];
            let mut g0 = 0;
            while g0 < kp / 2 {
                let ge = (g0 + SPARSE_KB / 2).min(kp / 2);
                if kb_active(pm, g0 / (SPARSE_KB / 2)) {
                    for g in g0..ge {
                        let grp = &panel[g * 16..(g + 1) * 16];
                        let x0 = i32::from(frame[2 * g]);
                        let x1 = i32::from(frame[2 * g + 1]);
                        for (r, &b) in grp.iter().enumerate() {
                            let w0 = i32::from(((b << 4) as i8) >> 4);
                            let w1 = i32::from((b as i8) >> 4);
                            acc[r] += w0 * x0 + w1 * x1;
                        }
                    }
                }
                g0 = ge;
            }
            for (rl, &av) in acc.iter().enumerate().take(rows) {
                c32[(row0 - crow0 + rl) * n + j] = av;
            }
        }
    }
}
