//! Runtime-dispatched GEMM microkernels over packed weight panels.
//!
//! All kernels share one contract: `A` is packed into `PACK_MR`-row
//! panels stored k-major (see [`crate::linalg::pack::PackedMatrix`]),
//! `X` holds `n` time-major frames of length `k` (the engines' natural
//! input layout — no transpose anywhere), and `C` is `[m, n]` row-major.
//!
//! Each kernel computes a `PACK_MR x NR` register tile with SIMD lanes
//! along the **row** dimension: per k step it issues unit-stride panel
//! loads plus one broadcast per frame column, so every FMA chain is
//! independent and the weight stream is purely sequential — the access
//! pattern the paper's "fetch each weight once per block" argument
//! wants from the hardware prefetcher.  The finished tile is handed to
//! [`store_tile`], which fuses the accumulate / dequant-scale / bias /
//! activation epilogue into the single store pass over `C`.
//!
//! Dispatch is decided once per process by [`detect`], walking the ISA
//! ladder top-down per architecture:
//!
//! * x86-64: **AVX-VNNI** (`avxvnni`, 4-way u8 x s8 `vpdpbusd` integer
//!   kernels; f32 still runs the AVX2 kernels) > **AVX2+FMA** > portable;
//! * aarch64: **NEON dotprod** (`sdot`, 4-way s8 x s8 integer kernels;
//!   f32 still runs the NEON kernels) > **NEON** > portable.
//!
//! `MTSRNN_ISA=portable|avx2|vnni|neon|sdot` pins any rung the host
//! supports (`MTSRNN_FORCE_PORTABLE=1` survives as an alias for
//! `portable`).  The portable kernel doubles as the correctness oracle
//! for every intrinsic path (see `rust/tests/packed_gemm_parity.rs`):
//! the integer families accumulate exact i32, so all tiers are
//! bit-identical, not merely close.

// On the audited unsafe allowlist (see `tools/lint` and
// `docs/UNSAFE.md`): this module is the single boundary where checked
// safe Rust hands raw slices to the intrinsic kernels.  Every `unsafe`
// call below is preceded by the contract validation in
// [`crate::linalg::contract`] (debug builds and the `checks` feature)
// and carries a `// SAFETY:` argument for release builds.
#![allow(unsafe_code)]

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "aarch64")]
pub mod neon;
pub mod portable;
#[cfg(target_arch = "x86_64")]
pub mod vnni;

use std::sync::OnceLock;

use crate::linalg::pack::{Epilogue, PACK_MR};

/// Sparse-block test shared by every kernel family: block `kb` of the
/// current panel is active (must be computed) unless the panel's mask
/// words clear its bit.  `None` means dense — the branch is trivially
/// predictable and costs nothing in the k loop.  Inlined into the
/// microkernels' chunked k sweeps; see `pack::PanelMask` for the exact
/// skip-soundness argument.
#[inline(always)]
pub(crate) fn kb_active(pm: Option<&[u64]>, kb: usize) -> bool {
    match pm {
        None => true,
        Some(w) => (w[kb >> 6] >> (kb & 63)) & 1 != 0,
    }
}

/// Which microkernel family [`detect`] selected for this process.
///
/// Every variant exists on every architecture (so tier names parse and
/// print everywhere); whether one *runs* on the current host is
/// [`Simd::runs_on`]'s question, asserted at every handle construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Simd {
    /// x86-64 AVX-VNNI (`vpdpbusd` u8 x s8 4-way dot) integer kernels
    /// over k-quad panels; implies Avx2 (f32 runs the AVX2 kernels).
    Vnni,
    /// x86-64 AVX2 + FMA intrinsics (16x6 register tile).
    Avx2,
    /// aarch64 NEON dotprod (`sdot` s8 x s8 4-way dot) integer kernels
    /// over k-quad panels; implies Neon (f32 runs the NEON kernels).
    Sdot,
    /// aarch64 NEON intrinsics (16x4 register tile).
    Neon,
    /// Autovectorized fallback (16x4 tile) — also the correctness oracle.
    Portable,
}

impl Simd {
    pub fn name(self) -> &'static str {
        match self {
            Simd::Vnni => "vnni",
            Simd::Avx2 => "avx2",
            Simd::Sdot => "sdot",
            Simd::Neon => "neon",
            Simd::Portable => "portable",
        }
    }

    /// Whether a handle built for `self` may execute when the hardware
    /// probe returned `detected`: exactly `self`, the portable fallback,
    /// or one ladder rung down on the same architecture (VNNI detection
    /// verified avx2+fma; `dotprod` implies the NEON baseline).  This is
    /// the soundness predicate the `with_dispatch*` constructors assert,
    /// and what lets parity tests pin any supported rung in-process.
    pub fn runs_on(self, detected: Simd) -> bool {
        self == Simd::Portable
            || self == detected
            || matches!(
                (detected, self),
                (Simd::Vnni, Simd::Avx2) | (Simd::Sdot, Simd::Neon)
            )
    }
}

/// Pure hardware probe: the highest ladder rung the host supports,
/// ignoring every pinning environment variable.  [`supported_tiers`]
/// and the `with_dispatch*` soundness asserts key off this, so a pinned
/// process can still construct (and test) any tier the silicon has.
pub fn detect_host() -> Simd {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            if is_x86_feature_detected!("avxvnni") {
                return Simd::Vnni;
            }
            return Simd::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            if std::arch::is_aarch64_feature_detected!("dotprod") {
                return Simd::Sdot;
            }
            return Simd::Neon;
        }
    }
    Simd::Portable
}

/// Every ladder rung the host can execute, best first — the tier list
/// CI matrixes `MTSRNN_ISA` over (printed by `mtsrnn info`).  Ignores
/// the pinning env vars on purpose: it answers "what could run here",
/// not "what was picked".
pub fn supported_tiers() -> Vec<Simd> {
    let host = detect_host();
    [Simd::Vnni, Simd::Avx2, Simd::Sdot, Simd::Neon, Simd::Portable]
        .into_iter()
        .filter(|t| t.runs_on(host))
        .collect()
}

fn parse_isa(name: &str) -> Option<Simd> {
    match name {
        "portable" => Some(Simd::Portable),
        "avx2" => Some(Simd::Avx2),
        "vnni" => Some(Simd::Vnni),
        "neon" => Some(Simd::Neon),
        "sdot" => Some(Simd::Sdot),
        _ => None,
    }
}

/// One-time runtime CPU feature detection (cached for the process).
///
/// `MTSRNN_ISA=portable|avx2|vnni|neon|sdot` pins the process to one
/// ladder rung — tests and benches use it to cover every tier the host
/// supports; an unknown name or a tier the hardware lacks panics
/// loudly rather than silently falling back.  The older
/// `MTSRNN_FORCE_PORTABLE=1` (any value but `0`/empty) is kept as an
/// alias for `MTSRNN_ISA=portable` and doubles as an escape hatch on
/// hosts with broken feature detection.
pub fn detect() -> Simd {
    static LEVEL: OnceLock<Simd> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let host = detect_host();
        if let Ok(v) = std::env::var("MTSRNN_ISA") {
            if !v.is_empty() {
                let want = parse_isa(&v).unwrap_or_else(|| {
                    panic!("MTSRNN_ISA={v}: unknown tier (expected portable|avx2|vnni|neon|sdot)")
                });
                assert!(
                    want.runs_on(host),
                    "MTSRNN_ISA={v}: tier not supported on this host (detected {})",
                    host.name()
                );
                return want;
            }
        }
        if std::env::var("MTSRNN_FORCE_PORTABLE").is_ok_and(|v| !v.is_empty() && v != "0") {
            return Simd::Portable;
        }
        host
    })
}

/// `c[m, n] (+)= panels @ x^T` with the epilogue fused into the store.
///
/// `panels` is the packed form of `A[m, k]`; `x` is `n` time-major
/// frames of length `k`.  `pm_all` is the block-sparsity bitmap in
/// `PanelMask::for_kernels` form (`None` = dense).
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul(
    simd: Simd,
    panels: &[f32],
    c: &mut [f32],
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    pm_all: Option<(&[u64], usize)>,
) {
    matmul_range(simd, panels, c, 0, x, m, k, n, acc, epi, pm_all, 0, m.div_ceil(PACK_MR));
}

/// Panel-range variant of [`matmul`]: computes only panels `p0..p1`
/// (output rows `p0 * PACK_MR .. min(p1 * PACK_MR, m)`).  `c` is the
/// caller's *sub-slice* for exactly those rows and `crow0 = p0 *
/// PACK_MR` is the absolute row index of `c[0]` (bias / scale /
/// activation lookups stay absolute).  This is the unit the worker pool
/// steals: disjoint panel ranges write disjoint `c` sub-slices, so the
/// multicore result is bit-identical to the serial full-range sweep.
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_range(
    simd: Simd,
    panels: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    // Checked contracts (debug builds + the `checks` feature): validate
    // every precondition the unsafe kernels rely on before dispatch.
    #[cfg(any(debug_assertions, feature = "checks"))]
    if let Err(e) = crate::linalg::contract::check_f32_dispatch(
        simd,
        panels,
        c.len(),
        crow0,
        x,
        m,
        k,
        n,
        epi,
        pm_all,
        p0,
        p1,
    ) {
        panic!("f32 kernel contract violated: {e}");
    }
    match simd {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: an Avx2/Vnni request only exists when `detect_host()`
        // verified avx2+fma on this host (constructors assert
        // `Simd::runs_on`; VNNI detection requires avx2+fma too).  The
        // f32 family has no VNNI kernel — dot instructions are
        // integer-only — so Vnni routes to the AVX2 f32 kernels.
        Simd::Avx2 | Simd::Vnni => unsafe {
            avx2::matmul(panels, c, crow0, x, m, k, n, acc, epi, pm_all, p0, p1)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 (and implied by `dotprod`);
        // `detect_host()` verifies it.  Sdot routes f32 to the NEON
        // kernels for the same reason Vnni routes to AVX2.
        Simd::Neon | Simd::Sdot => unsafe {
            neon::matmul(panels, c, crow0, x, m, k, n, acc, epi, pm_all, p0, p1)
        },
        _ => portable::matmul(panels, c, crow0, x, m, k, n, acc, epi, pm_all, p0, p1),
    }
}

/// q8q integer GEMM over the dispatched tier's interleaved i8 panels
/// (pair layout for AVX2/NEON/portable — `pack::pack_panels_q8q` — and
/// quad layout for VNNI/sdot — `pack::pack_panels_q8q_quad`):
/// `c32[m, n] = panels @ xq^T` with pure i32 accumulation — **no f32
/// anywhere**.  `xq` holds `n` quantized frames of length `kp` (i8);
/// `qpair` is the same data as packed i16 pairs (the AVX2 broadcast
/// form); `qshift` is the +128-shifted u8 form with `corr` the packed
/// per-row zero-point corrections (the VNNI pair — empty slices on
/// every other tier).  Because every product is exact and integer
/// addition is associative, all kernel families produce bit-identical
/// accumulators, and disjoint panel ranges make the pool-fanned sweep
/// bit-identical to the serial one.
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_q8q(
    simd: Simd,
    qpanels: &[i8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    qpair: &[i32],
    qshift: &[u8],
    corr: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    // Each architecture consumes one broadcast form; keep every name
    // live so no cfg arm trips unused-variable lints.
    let _ = (&xq, &qpair, &qshift, &corr);
    #[cfg(any(debug_assertions, feature = "checks"))]
    if let Err(e) = crate::linalg::contract::check_q8q_dispatch(
        simd,
        qpanels,
        c32.len(),
        crow0,
        xq,
        qpair,
        qshift,
        corr,
        m,
        kp,
        n,
        pm_all,
        p0,
        p1,
    ) {
        panic!("q8q kernel contract violated: {e}");
    }
    match simd {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: a Vnni request only exists when `detect_host()`
        // verified avxvnni (+avx2+fma) on this host — constructors
        // assert `Simd::runs_on`.
        Simd::Vnni => unsafe {
            vnni::matmul_q8q(qpanels, c32, crow0, qshift, corr, m, kp, n, pm_all, p0, p1)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: an Avx2 request only exists when `detect_host()`
        // verified avx2+fma on this host (constructors assert
        // `Simd::runs_on`).
        Simd::Avx2 => unsafe {
            avx2::matmul_q8q(qpanels, c32, crow0, qpair, m, kp, n, pm_all, p0, p1)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: an Sdot request only exists when `detect_host()`
        // verified `dotprod` on this host (constructors assert
        // `Simd::runs_on`).
        Simd::Sdot => unsafe {
            neon::matmul_q8q_sdot(qpanels, c32, crow0, xq, m, kp, n, pm_all, p0, p1)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; `detect_host()` verifies it.
        Simd::Neon => unsafe {
            neon::matmul_q8q(qpanels, c32, crow0, xq, m, kp, n, pm_all, p0, p1)
        },
        _ => portable::matmul_q8q(qpanels, c32, crow0, xq, m, kp, n, pm_all, p0, p1),
    }
}

/// q4 integer GEMM over nibble-packed panels (pair layout
/// `pack::pack_panels_q4` for AVX2/NEON/portable, tier-specific quad
/// layout `pack::pack_panels_q4_quad` for VNNI/sdot):
/// `c32[m, n] = panels @ xq^T` with in-register nibble unpack and pure
/// i32 accumulation — the q8q contract (exact, order-independent,
/// bit-identical across kernel families and thread counts) at **half**
/// the weight byte stream.  `xq`/`qpair`/`qshift`/`corr` are the same
/// quantized activation forms q8q consumes.
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_q4(
    simd: Simd,
    q4panels: &[u8],
    c32: &mut [i32],
    crow0: usize,
    xq: &[i8],
    qpair: &[i32],
    qshift: &[u8],
    corr: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    // Each architecture consumes one broadcast form; keep every name
    // live so no cfg arm trips unused-variable lints.
    let _ = (&xq, &qpair, &qshift, &corr);
    #[cfg(any(debug_assertions, feature = "checks"))]
    if let Err(e) = crate::linalg::contract::check_q4_dispatch(
        simd,
        q4panels,
        c32.len(),
        crow0,
        xq,
        qpair,
        qshift,
        corr,
        m,
        kp,
        n,
        pm_all,
        p0,
        p1,
    ) {
        panic!("q4 kernel contract violated: {e}");
    }
    match simd {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: a Vnni request only exists when `detect_host()`
        // verified avxvnni (+avx2+fma) on this host — constructors
        // assert `Simd::runs_on`.
        Simd::Vnni => unsafe {
            vnni::matmul_q4(q4panels, c32, crow0, qshift, corr, m, kp, n, pm_all, p0, p1)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: an Avx2 request only exists when `detect_host()`
        // verified avx2+fma on this host (constructors assert
        // `Simd::runs_on`).
        Simd::Avx2 => unsafe {
            avx2::matmul_q4(q4panels, c32, crow0, qpair, m, kp, n, pm_all, p0, p1)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: an Sdot request only exists when `detect_host()`
        // verified `dotprod` on this host (constructors assert
        // `Simd::runs_on`).
        Simd::Sdot => unsafe {
            neon::matmul_q4_sdot(q4panels, c32, crow0, xq, m, kp, n, pm_all, p0, p1)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; `detect_host()` verifies it.
        Simd::Neon => unsafe {
            neon::matmul_q4(q4panels, c32, crow0, xq, m, kp, n, pm_all, p0, p1)
        },
        _ => portable::matmul_q4(q4panels, c32, crow0, xq, m, kp, n, pm_all, p0, p1),
    }
}

/// Store one finished `PACK_MR x nr` i32 register tile into the raw
/// accumulator block (same sub-slice/absolute-row contract as
/// [`store_tile`]; no epilogue — dequantization happens in
/// `pack::dequant_rows`, the single shared f32 touch point).
/// (Used by the intrinsic kernels; the portable kernel stores per
/// column, hence the dead-code allowance on intrinsic-free targets.)
#[allow(clippy::too_many_arguments, dead_code)]
pub(crate) fn store_tile_i32(
    c32: &mut [i32],
    crow0: usize,
    tile: &[[i32; PACK_MR]],
    j0: usize,
    nr: usize,
    row0: usize,
    m: usize,
    n: usize,
) {
    let rows = PACK_MR.min(m - row0);
    for r in 0..rows {
        let row = row0 + r;
        let crow = &mut c32[(row - crow0) * n + j0..(row - crow0) * n + j0 + nr];
        for (jj, cv) in crow.iter_mut().enumerate() {
            *cv = tile[jj][r];
        }
    }
}

/// Store one finished `PACK_MR x nr` register tile into `C`, fusing the
/// whole epilogue into the only pass over the output:
///
/// ```text
/// C[row, j] = act(tile * scale + bias (+ C[row, j] if acc))
/// ```
///
/// `c` may be a row sub-slice of the full output: `crow0` is the
/// absolute row index of `c[0]` (0 for a full-matrix sweep), while
/// `row0`/`m` stay absolute so bias, scale and the activation segment
/// map are unchanged under panel-range parallel execution.
///
/// Rows past `m` are panel zero-padding: computed, never stored.
#[allow(clippy::too_many_arguments)]
pub(crate) fn store_tile(
    c: &mut [f32],
    crow0: usize,
    tile: &[[f32; PACK_MR]],
    j0: usize,
    nr: usize,
    row0: usize,
    m: usize,
    n: usize,
    acc: bool,
    scale: Option<&[f32]>,
    epi: &Epilogue,
) {
    let rows = PACK_MR.min(m - row0);
    for r in 0..rows {
        let row = row0 + r;
        let s = scale.map_or(1.0, |sc| sc[row]);
        let b = epi.bias.map_or(0.0, |bias| bias[row]);
        let act = epi.act_for_row(m, row);
        let crow = &mut c[(row - crow0) * n + j0..(row - crow0) * n + j0 + nr];
        for (jj, cv) in crow.iter_mut().enumerate() {
            let mut v = tile[jj][r] * s + b;
            if acc {
                v += *cv;
            }
            *cv = act.apply(v);
        }
    }
}

// The dispatch-boundary contract wiring: active in debug builds and
// under `--features checks`, so these tests are gated the same way
// (plain `cargo test` runs them; a bare release build skips them).
#[cfg(test)]
#[cfg(any(debug_assertions, feature = "checks"))]
mod contract_wiring_tests {
    use super::*;

    fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
        payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default()
    }

    #[test]
    fn f32_dispatch_rejects_wrong_panel_stride() {
        let (m, k, n) = (16usize, 8usize, 2usize);
        let panels = vec![0.0f32; PACK_MR * k - 1]; // one float short
        let x = vec![0.0f32; n * k];
        let mut c = vec![0.0f32; m * n];
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            matmul(Simd::Portable, &panels, &mut c, &x, m, k, n, false, &Epilogue::NONE, None);
        }))
        .unwrap_err();
        let msg = panic_message(payload);
        assert!(msg.contains("f32 kernel contract violated"), "{msg}");
    }

    #[test]
    fn f32_dispatch_rejects_short_mask() {
        let (m, k, n) = (40usize, 64usize, 2usize);
        let np = m.div_ceil(PACK_MR);
        let panels = vec![0.0f32; np * PACK_MR * k];
        let x = vec![0.0f32; n * k];
        let mut c = vec![0.0f32; m * n];
        let words = vec![u64::MAX; np - 1]; // wpp = 1, one panel short
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            matmul(
                Simd::Portable,
                &panels,
                &mut c,
                &x,
                m,
                k,
                n,
                false,
                &Epilogue::NONE,
                Some((&words, 1)),
            );
        }))
        .unwrap_err();
        let msg = panic_message(payload);
        assert!(msg.contains("mask"), "{msg}");
    }

    #[test]
    fn q8q_dispatch_rejects_odd_kp() {
        let (m, kp, n) = (16usize, 7usize, 1usize);
        let qpanels = vec![0i8; PACK_MR * kp];
        let xq = vec![0i8; n * kp];
        let qpair = vec![0i32; n * (kp / 2)];
        let mut c32 = vec![0i32; m * n];
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            matmul_q8q(
                Simd::Portable,
                &qpanels,
                &mut c32,
                0,
                &xq,
                &qpair,
                &[],
                &[],
                m,
                kp,
                n,
                None,
                0,
                1,
            );
        }))
        .unwrap_err();
        let msg = panic_message(payload);
        assert!(msg.contains("q8q kernel contract violated"), "{msg}");
    }

    #[test]
    fn q4_dispatch_rejects_overlapping_output_range() {
        let (m, kp, n) = (32usize, 8usize, 2usize);
        let np = m.div_ceil(PACK_MR);
        let q4panels = vec![0u8; np * (PACK_MR / 2) * kp];
        let xq = vec![0i8; n * kp];
        let qpair = vec![0i32; n * kp / 2];
        // Range 1..2 with crow0 = 0 would alias panel 0's output rows.
        let mut c32 = vec![0i32; PACK_MR * n];
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            matmul_q4(
                Simd::Portable,
                &q4panels,
                &mut c32,
                0,
                &xq,
                &qpair,
                &[],
                &[],
                m,
                kp,
                n,
                None,
                1,
                2,
            );
        }))
        .unwrap_err();
        let msg = panic_message(payload);
        assert!(msg.contains("crow0"), "{msg}");
    }
}
