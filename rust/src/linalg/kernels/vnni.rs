//! AVX-VNNI integer microkernel: 16-row panels x 6-column register
//! tile over k-quad-interleaved panels.
//!
//! Per k-quad: two 32-byte unit-stride panel loads plus one 4-byte
//! activation broadcast per frame column feed `2 * NR` independent
//! `vpdpbusd` chains — each instruction retires **4 MACs per output
//! row** (64 per ymm), twice the `madd_epi16` pair rate of the AVX2
//! tier for the same weight stream.
//!
//! `vpdpbusd` multiplies *unsigned* bytes by signed bytes, so the
//! activations arrive pre-shifted by the +128 zero point (`qshift` in
//! [`crate::linalg::pack::QuantScratch`]) and every accumulator is
//! **initialized at `-corr[row]`** where `corr[row] = 128 * sum_k w` —
//! by `sum_k w * (x + 128) - 128 * sum_k w == sum_k w * x` the final
//! value is the exact signed dot product, bit-identical to every other
//! kernel family.  The `VNNI_Q8_MAX_K` / `VNNI_Q4_MAX_K` bounds keep
//! every intermediate (the un-cancelled correction prefix plus shifted
//! partial sums) inside i32, so no wrap ever occurs.

// On the audited unsafe allowlist (see `tools/lint` and
// `docs/UNSAFE.md`).  Under `deny(unsafe_op_in_unsafe_fn)` the value
// intrinsics are safe inside these `#[target_feature]` functions; the
// `unsafe {}` blocks below mark exactly the raw-pointer operations,
// each with the bound that keeps it in range.  The bounds themselves
// are validated at the dispatch boundary by `linalg::contract`.
#![allow(unsafe_code)]

use core::arch::x86_64::{
    __m256i, _mm256_and_si256, _mm256_dpbusd_avx_epi32, _mm256_loadu_si256, _mm256_set1_epi8,
    _mm256_set1_epi32, _mm256_setzero_si256, _mm256_srli_epi16, _mm256_storeu_si256,
    _mm256_sub_epi8, _mm256_sub_epi32, _mm256_unpackhi_epi8, _mm256_unpacklo_epi8,
    _mm256_xor_si256,
};

use super::{kb_active, store_tile_i32};
use crate::linalg::pack::{PACK_MR, SPARSE_KB};

/// Register-tile width (frame columns per microkernel pass) — same
/// 16x6 tile shape as the AVX2 tier: 12 ymm accumulators + 2 weight
/// registers + 1 broadcast fill the 16-register ymm file.
pub(crate) const NR: usize = 6;

macro_rules! def_kern_q8q {
    ($name:ident, $nr:literal) => {
        /// q8q VNNI microkernel: per k-quad `g` (`kk = 4g`), the two
        /// 32-byte halves of the 64-byte quad group (row-major quads;
        /// i32 lane `l` = row `l` / `8 + l`) each take one `vpdpbusd`
        /// against the broadcast `[xu_{4g} .. xu_{4g+3}]` u8 quad.
        /// Accumulators start at `-corr` (see the module docs), so the
        /// finished lane is the exact signed dot product.
        ///
        /// # Safety
        /// Requires avx2+avxvnni.  `panel` must hold `kp * PACK_MR`
        /// bytes in the quad-interleaved q8q layout, `qshift` at least
        /// `(j0 + $nr) * kp` shifted bytes, and `corrp` this panel's
        /// `PACK_MR` correction terms.
        #[target_feature(enable = "avx2,avxvnni")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const i8,
            qshift: *const u8,
            corrp: *const i32,
            kp: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            // SAFETY: caller guarantees `corrp` points at PACK_MR i32
            // corrections, so both 8-lane loads stay in bounds.
            let (c0, c1) = unsafe {
                (
                    _mm256_loadu_si256(corrp as *const __m256i),
                    _mm256_loadu_si256(corrp.add(8) as *const __m256i),
                )
            };
            let zero = _mm256_setzero_si256();
            let mut lo = [_mm256_sub_epi32(zero, c0); $nr];
            let mut hi = [_mm256_sub_epi32(zero, c1); $nr];
            let mut frames = [qshift; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `qshift` holds
                // `(j0 + $nr) * kp` bytes, so frame `j0 + jj` starts in
                // bounds.
                *f = unsafe { qshift.add((j0 + jj) * kp) };
            }
            // Quad loop chunked at SPARSE_KB / 4 quads per sparse
            // block; skipping is exact (skipped blocks are all-zero
            // weights, contributing 0 to both the dot and `corr`), so
            // results stay bit-identical to the dense sweep.
            let mut g0 = 0usize;
            while g0 < kp / 4 {
                let ge = (g0 + SPARSE_KB / 4).min(kp / 4);
                if kb_active(pm, g0 / (SPARSE_KB / 4)) {
                    for g in g0..ge {
                        // SAFETY: g < kp / 4 and the quad-interleaved
                        // panel holds kp * PACK_MR = (kp / 4) * 64
                        // bytes, so both 32-byte loads stay inside
                        // quad-group g.
                        let w0 = unsafe { _mm256_loadu_si256(panel.add(g * 64) as *const __m256i) };
                        // SAFETY: as above, second half of group g.
                        let w1 =
                            unsafe { _mm256_loadu_si256(panel.add(g * 64 + 32) as *const __m256i) };
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at a kp-byte
                            // frame and 4 * g + 3 < kp.
                            let q = unsafe {
                                (frames[jj].add(4 * g) as *const i32).read_unaligned()
                            };
                            let b = _mm256_set1_epi32(q);
                            lo[jj] = _mm256_dpbusd_avx_epi32(lo[jj], b, w0);
                            hi[jj] = _mm256_dpbusd_avx_epi32(hi[jj], b, w1);
                        }
                    }
                }
                g0 = ge;
            }
            for jj in 0..$nr {
                // SAFETY: tile[jj] is [i32; PACK_MR] = 16 lanes; the
                // two 8-lane stores cover exactly elements 0..16.
                unsafe {
                    _mm256_storeu_si256(tile[jj].as_mut_ptr() as *mut __m256i, lo[jj]);
                    _mm256_storeu_si256(tile[jj].as_mut_ptr().add(8) as *mut __m256i, hi[jj]);
                }
            }
        }
    };
}

def_kern_q8q!(kv1, 1);
def_kern_q8q!(kv2, 2);
def_kern_q8q!(kv3, 3);
def_kern_q8q!(kv4, 4);
def_kern_q8q!(kv5, 5);
def_kern_q8q!(kv6, 6);

/// q8q integer GEMM over quad-interleaved panels; same panel-range /
/// sub-slice contract as the AVX2 driver, writing raw i32 accumulators.
///
/// # Safety
/// Requires avx2+avxvnni (guaranteed by the `detect_host()` gate behind
/// the dispatcher).  The caller must uphold the dispatch contract
/// validated by `contract::check_q8q_dispatch` at the Vnni tier:
/// `qpanels` holds `ceil(m / PACK_MR) * PACK_MR * kp` bytes with
/// `kp % 4 == 0` and within the `VNNI_Q8_MAX_K` exactness bound,
/// `qshift` holds `n * kp` shifted activation bytes, `corr` holds
/// `ceil(m / PACK_MR) * PACK_MR` per-row corrections,
/// `p0 <= p1 <= ceil(m / PACK_MR)`, `crow0 == p0 * PACK_MR`, and `c32`
/// covers exactly the range's rows.
#[target_feature(enable = "avx2,avxvnni")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q8q(
    qpanels: &[i8],
    c32: &mut [i32],
    crow0: usize,
    qshift: &[u8],
    corr: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(qpanels.len(), m.div_ceil(PACK_MR) * PACK_MR * kp);
    debug_assert_eq!(corr.len(), m.div_ceil(PACK_MR) * PACK_MR);
    debug_assert_eq!(kp % 4, 0);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = qpanels[pi * PACK_MR * kp..].as_ptr();
        let corrp = corr[pi * PACK_MR..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let qs = qshift.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `kp * PACK_MR`-byte quad
            // panel, `corrp` its PACK_MR corrections, and `qshift`
            // holds n * kp bytes with j0 + nr <= n — exactly each
            // kernel's documented requirement.
            unsafe {
                match nr {
                    6 => kv6(panel, qs, corrp, kp, j0, pm, &mut tile),
                    5 => kv5(panel, qs, corrp, kp, j0, pm, &mut tile),
                    4 => kv4(panel, qs, corrp, kp, j0, pm, &mut tile),
                    3 => kv3(panel, qs, corrp, kp, j0, pm, &mut tile),
                    2 => kv2(panel, qs, corrp, kp, j0, pm, &mut tile),
                    _ => kv1(panel, qs, corrp, kp, j0, pm, &mut tile),
                }
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}

macro_rules! def_kern_q4 {
    ($name:ident, $nr:literal) => {
        /// q4 VNNI microkernel: per k-quad, one 32-byte load carries
        /// **64 weights** (two signed nibbles per byte).  Sign
        /// extension stays in the byte domain — AVX2 has no 8-bit
        /// shifts, so `(n & 0x0F) ^ 8 - 8` recovers the low nibble and
        /// the same trick on `(bytes >> 4) & 0x0F` the high one — then
        /// one `unpacklo/hi_epi8` pair rebuilds row-major quads.  The
        /// panel layout pre-compensates unpack's per-128-bit-lane
        /// traversal (`VNNI_Q4_GRP_BASE`), so no cross-lane permute is
        /// ever needed; the `vpdpbusd` accumulation and `-corr` init
        /// then match the q8q kernel exactly.
        ///
        /// # Safety
        /// Requires avx2+avxvnni.  `panel` must hold `kp * PACK_MR / 2`
        /// bytes in the VNNI nibble-quad layout, `qshift` at least
        /// `(j0 + $nr) * kp` shifted bytes, and `corrp` this panel's
        /// `PACK_MR` correction terms.
        #[target_feature(enable = "avx2,avxvnni")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const u8,
            qshift: *const u8,
            corrp: *const i32,
            kp: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            // SAFETY: caller guarantees `corrp` points at PACK_MR i32
            // corrections, so both 8-lane loads stay in bounds.
            let (c0, c1) = unsafe {
                (
                    _mm256_loadu_si256(corrp as *const __m256i),
                    _mm256_loadu_si256(corrp.add(8) as *const __m256i),
                )
            };
            let zero = _mm256_setzero_si256();
            let mut lo = [_mm256_sub_epi32(zero, c0); $nr];
            let mut hi = [_mm256_sub_epi32(zero, c1); $nr];
            let mut frames = [qshift; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `qshift` holds
                // `(j0 + $nr) * kp` bytes, so frame `j0 + jj` starts in
                // bounds.
                *f = unsafe { qshift.add((j0 + jj) * kp) };
            }
            let nib = _mm256_set1_epi8(0x0F);
            let sgn = _mm256_set1_epi8(0x08);
            let mut g0 = 0usize;
            while g0 < kp / 4 {
                let ge = (g0 + SPARSE_KB / 4).min(kp / 4);
                if kb_active(pm, g0 / (SPARSE_KB / 4)) {
                    for g in g0..ge {
                        // SAFETY: g < kp / 4 and the nibble-quad panel
                        // holds (kp / 4) * 32 bytes, so the 32-byte
                        // load covers exactly quad-group g.
                        let raw =
                            unsafe { _mm256_loadu_si256(panel.add(g * 32) as *const __m256i) };
                        // Byte-domain nibble sign extension: for
                        // n in 0..16, ((n ^ 8) - 8) maps 0..8 -> n and
                        // 8..16 -> n - 16; sub_epi8 borrows never cross
                        // byte lanes.
                        let ln = _mm256_sub_epi8(
                            _mm256_xor_si256(_mm256_and_si256(raw, nib), sgn),
                            sgn,
                        );
                        let hn = _mm256_sub_epi8(
                            _mm256_xor_si256(
                                _mm256_and_si256(_mm256_srli_epi16(raw, 4), nib),
                                sgn,
                            ),
                            sgn,
                        );
                        let w0 = _mm256_unpacklo_epi8(ln, hn);
                        let w1 = _mm256_unpackhi_epi8(ln, hn);
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at a kp-byte
                            // frame and 4 * g + 3 < kp.
                            let q = unsafe {
                                (frames[jj].add(4 * g) as *const i32).read_unaligned()
                            };
                            let b = _mm256_set1_epi32(q);
                            lo[jj] = _mm256_dpbusd_avx_epi32(lo[jj], b, w0);
                            hi[jj] = _mm256_dpbusd_avx_epi32(hi[jj], b, w1);
                        }
                    }
                }
                g0 = ge;
            }
            for jj in 0..$nr {
                // SAFETY: tile[jj] is [i32; PACK_MR] = 16 lanes; the
                // two 8-lane stores cover exactly elements 0..16.
                unsafe {
                    _mm256_storeu_si256(tile[jj].as_mut_ptr() as *mut __m256i, lo[jj]);
                    _mm256_storeu_si256(tile[jj].as_mut_ptr().add(8) as *mut __m256i, hi[jj]);
                }
            }
        }
    };
}

def_kern_q4!(kv41, 1);
def_kern_q4!(kv42, 2);
def_kern_q4!(kv43, 3);
def_kern_q4!(kv44, 4);
def_kern_q4!(kv45, 5);
def_kern_q4!(kv46, 6);

/// q4 integer GEMM over VNNI nibble-quad panels; same panel-range /
/// sub-slice contract as the AVX2 driver, writing raw i32 accumulators.
///
/// # Safety
/// Requires avx2+avxvnni (guaranteed by the `detect_host()` gate behind
/// the dispatcher).  The caller must uphold the dispatch contract
/// validated by `contract::check_q4_dispatch` at the Vnni tier:
/// `q4panels` holds `ceil(m / PACK_MR) * (PACK_MR / 2) * kp` bytes with
/// `kp % 4 == 0` and within the `VNNI_Q4_MAX_K` exactness bound,
/// `qshift` holds `n * kp` shifted activation bytes, `corr` holds
/// `ceil(m / PACK_MR) * PACK_MR` per-row corrections,
/// `p0 <= p1 <= ceil(m / PACK_MR)`, `crow0 == p0 * PACK_MR`, and `c32`
/// covers exactly the range's rows.
#[target_feature(enable = "avx2,avxvnni")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q4(
    q4panels: &[u8],
    c32: &mut [i32],
    crow0: usize,
    qshift: &[u8],
    corr: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(q4panels.len(), m.div_ceil(PACK_MR) * (PACK_MR / 2) * kp);
    debug_assert_eq!(corr.len(), m.div_ceil(PACK_MR) * PACK_MR);
    debug_assert_eq!(kp % 4, 0);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = q4panels[pi * (PACK_MR / 2) * kp..].as_ptr();
        let corrp = corr[pi * PACK_MR..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let qs = qshift.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `(kp / 4) * 32`-byte
            // nibble-quad panel, `corrp` its PACK_MR corrections, and
            // `qshift` holds n * kp bytes with j0 + nr <= n — exactly
            // each kernel's documented requirement.
            unsafe {
                match nr {
                    6 => kv46(panel, qs, corrp, kp, j0, pm, &mut tile),
                    5 => kv45(panel, qs, corrp, kp, j0, pm, &mut tile),
                    4 => kv44(panel, qs, corrp, kp, j0, pm, &mut tile),
                    3 => kv43(panel, qs, corrp, kp, j0, pm, &mut tile),
                    2 => kv42(panel, qs, corrp, kp, j0, pm, &mut tile),
                    _ => kv41(panel, qs, corrp, kp, j0, pm, &mut tile),
                }
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}
