//! AVX2+FMA microkernel: 16-row panels x 6-column register tile.
//!
//! Per k step: two 8-lane unit-stride panel loads plus one broadcast per
//! frame column feed `2 * NR` independent FMA chains — at `NR = 6` that
//! is 12 ymm accumulators + 2 panel registers + 1 broadcast register,
//! filling the 16-register ymm file (the classic GEBP shape).  The tile
//! is spilled to a 384-byte stack buffer once per full-K sweep and the
//! epilogue-fused store runs from there; at K >= 256 the spill is noise.

// On the audited unsafe allowlist (see `tools/lint` and
// `docs/UNSAFE.md`).  Under `deny(unsafe_op_in_unsafe_fn)` the value
// intrinsics are safe inside these `#[target_feature]` functions; the
// `unsafe {}` blocks below mark exactly the raw-pointer operations,
// each with the bound that keeps it in range.  The bounds themselves
// are validated at the dispatch boundary by `linalg::contract`.
#![allow(unsafe_code)]

use core::arch::x86_64::{
    __m128i, __m256i, _mm256_add_epi32, _mm256_cvtepi8_epi16, _mm256_fmadd_ps, _mm256_loadu_ps,
    _mm256_madd_epi16, _mm256_permute2x128_si256, _mm256_set1_epi32, _mm256_set1_ps,
    _mm256_setzero_ps, _mm256_setzero_si256, _mm256_slli_epi16, _mm256_srai_epi16,
    _mm256_storeu_ps, _mm256_storeu_si256, _mm256_unpackhi_epi16, _mm256_unpacklo_epi16,
    _mm_loadu_si128,
};

use super::{kb_active, store_tile, store_tile_i32};
use crate::linalg::pack::{Epilogue, PACK_MR, SPARSE_KB};

/// Register-tile width (frame columns per microkernel pass).
pub(crate) const NR: usize = 6;

macro_rules! def_kern {
    ($name:ident, $nr:literal) => {
        /// # Safety
        /// Requires avx2+fma.  `panel` must hold `k * PACK_MR` floats and
        /// `x` must hold at least `(j0 + $nr) * k` floats.
        #[target_feature(enable = "avx2,fma")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const f32,
            x: *const f32,
            k: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[f32; PACK_MR]; NR],
        ) {
            let mut acc0 = [_mm256_setzero_ps(); $nr];
            let mut acc1 = [_mm256_setzero_ps(); $nr];
            let mut frames = [x; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `x` holds `(j0 + $nr) * k`
                // floats, so frame `j0 + jj` starts in bounds.
                *f = unsafe { x.add((j0 + jj) * k) };
            }
            // K walks in SPARSE_KB chunks; skipping an inactive (all
            // exactly zero) block keeps the surviving FMA chain in
            // order, so the result matches the dense sweep bitwise.
            let mut kb0 = 0usize;
            while kb0 < k {
                let ke = (kb0 + SPARSE_KB).min(k);
                if kb_active(pm, kb0 / SPARSE_KB) {
                    for kk in kb0..ke {
                        // SAFETY: kk < k and the panel holds
                        // `k * PACK_MR` floats, so both 8-lane loads
                        // stay inside panel column kk.
                        let a0 = unsafe { _mm256_loadu_ps(panel.add(kk * PACK_MR)) };
                        // SAFETY: as above, second half of column kk.
                        let a1 = unsafe { _mm256_loadu_ps(panel.add(kk * PACK_MR + 8)) };
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at a k-float
                            // frame and kk < k.
                            let b = _mm256_set1_ps(unsafe { *frames[jj].add(kk) });
                            acc0[jj] = _mm256_fmadd_ps(a0, b, acc0[jj]);
                            acc1[jj] = _mm256_fmadd_ps(a1, b, acc1[jj]);
                        }
                    }
                }
                kb0 = ke;
            }
            for jj in 0..$nr {
                // SAFETY: tile[jj] is [f32; PACK_MR] = 16 floats; the
                // two 8-lane stores cover exactly elements 0..16.
                unsafe {
                    _mm256_storeu_ps(tile[jj].as_mut_ptr(), acc0[jj]);
                    _mm256_storeu_ps(tile[jj].as_mut_ptr().add(8), acc1[jj]);
                }
            }
        }
    };
}

def_kern!(kern1, 1);
def_kern!(kern2, 2);
def_kern!(kern3, 3);
def_kern!(kern4, 4);
def_kern!(kern5, 5);
def_kern!(kern6, 6);

/// `c` covers rows `crow0..` of the output; `p0..p1` is the panel range
/// to compute (full sweep: `crow0 = 0`, `p0 = 0`, `p1 = ceil(m / MR)`).
///
/// # Safety
/// Requires avx2+fma (guaranteed by the `detect()` gate in the
/// dispatcher).  The caller must uphold the dispatch contract validated
/// by `contract::check_f32_dispatch`: `panels` holds
/// `ceil(m / PACK_MR) * PACK_MR * k` floats, `x` holds `n * k` floats,
/// `p0 <= p1 <= ceil(m / PACK_MR)`, `crow0 == p0 * PACK_MR`, `c` covers
/// exactly the range's rows, and any mask carries
/// `ceil(ceil(k / SPARSE_KB) / 64)` words per panel.
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul(
    panels: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(panels.len(), m.div_ceil(PACK_MR) * PACK_MR * k);
    let mut tile = [[0f32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = panels[pi * PACK_MR * k..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let xp = x.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `k * PACK_MR` panel
            // (pi < p1 <= np and panels.len() == np * PACK_MR * k) and
            // `x` holds n * k floats with j0 + nr <= n — exactly each
            // kernel's documented requirement.
            unsafe {
                match nr {
                    6 => kern6(panel, xp, k, j0, pm, &mut tile),
                    5 => kern5(panel, xp, k, j0, pm, &mut tile),
                    4 => kern4(panel, xp, k, j0, pm, &mut tile),
                    3 => kern3(panel, xp, k, j0, pm, &mut tile),
                    2 => kern2(panel, xp, k, j0, pm, &mut tile),
                    _ => kern1(panel, xp, k, j0, pm, &mut tile),
                }
            }
            store_tile(c, crow0, &tile, j0, nr, pi * PACK_MR, m, n, acc, None, epi);
            j0 += nr;
        }
    }
}

macro_rules! def_kern_q8q {
    ($name:ident, $nr:literal) => {
        /// q8q integer microkernel: per k-pair, the two 16-byte panel
        /// halves sign-extend to i16 (`cvtepi8_epi16`) and one
        /// `madd_epi16` against the broadcast `[x_{2g}, x_{2g+1}]` i16
        /// pair yields row-wise exact two-product i32 partial sums — 16
        /// MACs per multiply instruction, twice the f32 FMA rate, with
        /// zero saturation risk (|w|, |x| <= 127 keeps every pair sum in
        /// i32 trivially; this is why `maddubs_epi16` was rejected — its
        /// i16 pair saturation would break bit-exact kernel parity).
        ///
        /// # Safety
        /// Requires avx2.  `panel` must hold `kp * PACK_MR` bytes in the
        /// pair-interleaved q8q layout and `qpair` at least
        /// `(j0 + $nr) * kp / 2` packed pairs.
        #[target_feature(enable = "avx2")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const i8,
            qpair: *const i32,
            kp: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            let mut lo = [_mm256_setzero_si256(); $nr];
            let mut hi = [_mm256_setzero_si256(); $nr];
            let mut frames = [qpair; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `qpair` holds
                // `(j0 + $nr) * kp / 2` pairs, so frame `j0 + jj`
                // starts in bounds.
                *f = unsafe { qpair.add((j0 + jj) * (kp / 2)) };
            }
            // Pair loop chunked at SPARSE_KB / 2 pairs per sparse
            // block; skipping is exact (i32) so results stay
            // bit-identical to the dense sweep.
            let mut g0 = 0usize;
            while g0 < kp / 2 {
                let ge = (g0 + SPARSE_KB / 2).min(kp / 2);
                if kb_active(pm, g0 / (SPARSE_KB / 2)) {
                    for g in g0..ge {
                        // SAFETY: g < kp / 2 and the pair-interleaved
                        // panel holds kp * PACK_MR = (kp / 2) * 32
                        // bytes, so both 16-byte loads stay inside
                        // pair-group g.
                        let w0 = unsafe {
                            _mm256_cvtepi8_epi16(_mm_loadu_si128(
                                panel.add(g * 32) as *const __m128i
                            ))
                        };
                        // SAFETY: as above, second half of group g.
                        let w1 = unsafe {
                            _mm256_cvtepi8_epi16(_mm_loadu_si128(
                                panel.add(g * 32 + 16) as *const __m128i,
                            ))
                        };
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at kp / 2
                            // packed pairs and g < kp / 2.
                            let b = _mm256_set1_epi32(unsafe { *frames[jj].add(g) });
                            lo[jj] = _mm256_add_epi32(lo[jj], _mm256_madd_epi16(w0, b));
                            hi[jj] = _mm256_add_epi32(hi[jj], _mm256_madd_epi16(w1, b));
                        }
                    }
                }
                g0 = ge;
            }
            for jj in 0..$nr {
                // SAFETY: tile[jj] is [i32; PACK_MR] = 16 lanes; the
                // two 8-lane stores cover exactly elements 0..16.
                unsafe {
                    _mm256_storeu_si256(tile[jj].as_mut_ptr() as *mut __m256i, lo[jj]);
                    _mm256_storeu_si256(tile[jj].as_mut_ptr().add(8) as *mut __m256i, hi[jj]);
                }
            }
        }
    };
}

def_kern_q8q!(kq1, 1);
def_kern_q8q!(kq2, 2);
def_kern_q8q!(kq3, 3);
def_kern_q8q!(kq4, 4);
def_kern_q8q!(kq5, 5);
def_kern_q8q!(kq6, 6);

/// q8q integer GEMM over pair-interleaved panels; same panel-range /
/// sub-slice contract as [`matmul`], writing raw i32 accumulators.
///
/// # Safety
/// Requires avx2 (guaranteed by the `detect()` gate in the dispatcher).
/// The caller must uphold the dispatch contract validated by
/// `contract::check_q8q_dispatch`: `qpanels` holds
/// `ceil(m / PACK_MR) * PACK_MR * kp` bytes with `kp` even and within
/// the i32-exactness bound, `qpair` holds `n * kp / 2` packed pairs,
/// `p0 <= p1 <= ceil(m / PACK_MR)`, `crow0 == p0 * PACK_MR`, and `c32`
/// covers exactly the range's rows.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q8q(
    qpanels: &[i8],
    c32: &mut [i32],
    crow0: usize,
    qpair: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(qpanels.len(), m.div_ceil(PACK_MR) * PACK_MR * kp);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = qpanels[pi * PACK_MR * kp..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let qp = qpair.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `kp * PACK_MR`-byte q8q
            // panel and `qpair` holds n * kp / 2 pairs with
            // j0 + nr <= n — exactly each kernel's requirement.
            unsafe {
                match nr {
                    6 => kq6(panel, qp, kp, j0, pm, &mut tile),
                    5 => kq5(panel, qp, kp, j0, pm, &mut tile),
                    4 => kq4(panel, qp, kp, j0, pm, &mut tile),
                    3 => kq3(panel, qp, kp, j0, pm, &mut tile),
                    2 => kq2(panel, qp, kp, j0, pm, &mut tile),
                    _ => kq1(panel, qp, kp, j0, pm, &mut tile),
                }
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}

macro_rules! def_kern_q4 {
    ($name:ident, $nr:literal) => {
        /// q4 integer microkernel: per k-pair, one 16-byte load carries
        /// **32 weights** (two signed nibbles per byte).  The byte
        /// vector sign-extends to i16 lanes once (`cvtepi8_epi16`);
        /// `slli 12 / srai 12` recovers the low nibble and `srai 4` the
        /// high one (the widened lane's top bits already replicate the
        /// high nibble's sign), then one `unpacklo/hi_epi16` pair
        /// rebuilds the `[w_{2g}, w_{2g+1}]` i16 pairing `madd_epi16`
        /// wants — same multiply throughput as q8q at half the weight
        /// bytes per k step, and exact i32 accumulation throughout
        /// (|pair sum| <= 2 * 7 * 127, nothing saturates).
        ///
        /// `unpack` interleaves per 128-bit lane, so the accumulators
        /// come out row-permuted — `acc_a` holds rows 0-3 / 8-11 and
        /// `acc_b` rows 4-7 / 12-15; one `permute2x128` pair at store
        /// time restores panel row order.
        ///
        /// # Safety
        /// Requires avx2.  `panel` must hold `kp * PACK_MR / 2` bytes in
        /// the nibble-packed q4 layout and `qpair` at least
        /// `(j0 + $nr) * kp / 2` packed pairs.
        #[target_feature(enable = "avx2")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const u8,
            qpair: *const i32,
            kp: usize,
            j0: usize,
            pm: Option<&[u64]>,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            let mut acc_a = [_mm256_setzero_si256(); $nr];
            let mut acc_b = [_mm256_setzero_si256(); $nr];
            let mut frames = [qpair; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                // SAFETY: caller guarantees `qpair` holds
                // `(j0 + $nr) * kp / 2` pairs, so frame `j0 + jj`
                // starts in bounds.
                *f = unsafe { qpair.add((j0 + jj) * (kp / 2)) };
            }
            let mut g0 = 0usize;
            while g0 < kp / 2 {
                let ge = (g0 + SPARSE_KB / 2).min(kp / 2);
                if kb_active(pm, g0 / (SPARSE_KB / 2)) {
                    for g in g0..ge {
                        // SAFETY: g < kp / 2 and the nibble-packed
                        // panel holds (kp / 2) * 16 bytes, so the
                        // 16-byte load covers exactly pair-group g.
                        let raw = unsafe { _mm_loadu_si128(panel.add(g * 16) as *const __m128i) };
                        let v = _mm256_cvtepi8_epi16(raw);
                        let lo = _mm256_srai_epi16(_mm256_slli_epi16(v, 12), 12);
                        let hi = _mm256_srai_epi16(v, 4);
                        let pa = _mm256_unpacklo_epi16(lo, hi);
                        let pb = _mm256_unpackhi_epi16(lo, hi);
                        for jj in 0..$nr {
                            // SAFETY: frames[jj] points at kp / 2
                            // packed pairs and g < kp / 2.
                            let b = _mm256_set1_epi32(unsafe { *frames[jj].add(g) });
                            acc_a[jj] = _mm256_add_epi32(acc_a[jj], _mm256_madd_epi16(pa, b));
                            acc_b[jj] = _mm256_add_epi32(acc_b[jj], _mm256_madd_epi16(pb, b));
                        }
                    }
                }
                g0 = ge;
            }
            for jj in 0..$nr {
                let r07 = _mm256_permute2x128_si256(acc_a[jj], acc_b[jj], 0x20);
                let r8f = _mm256_permute2x128_si256(acc_a[jj], acc_b[jj], 0x31);
                // SAFETY: tile[jj] is [i32; PACK_MR] = 16 lanes; the
                // two 8-lane stores cover exactly elements 0..16.
                unsafe {
                    _mm256_storeu_si256(tile[jj].as_mut_ptr() as *mut __m256i, r07);
                    _mm256_storeu_si256(tile[jj].as_mut_ptr().add(8) as *mut __m256i, r8f);
                }
            }
        }
    };
}

def_kern_q4!(k41, 1);
def_kern_q4!(k42, 2);
def_kern_q4!(k43, 3);
def_kern_q4!(k44, 4);
def_kern_q4!(k45, 5);
def_kern_q4!(k46, 6);

/// q4 integer GEMM over nibble-packed panels; same panel-range /
/// sub-slice contract as [`matmul`], writing raw i32 accumulators.
///
/// # Safety
/// Requires avx2 (guaranteed by the `detect()` gate in the dispatcher).
/// The caller must uphold the dispatch contract validated by
/// `contract::check_q4_dispatch`: `q4panels` holds
/// `ceil(m / PACK_MR) * (PACK_MR / 2) * kp` bytes with `kp` even and
/// within the q4 i32-exactness bound, `qpair` holds `n * kp / 2` packed
/// pairs, `p0 <= p1 <= ceil(m / PACK_MR)`, `crow0 == p0 * PACK_MR`, and
/// `c32` covers exactly the range's rows.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q4(
    q4panels: &[u8],
    c32: &mut [i32],
    crow0: usize,
    qpair: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    pm_all: Option<(&[u64], usize)>,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(q4panels.len(), m.div_ceil(PACK_MR) * (PACK_MR / 2) * kp);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = q4panels[pi * (PACK_MR / 2) * kp..].as_ptr();
        let pm = pm_all.map(|(bits, wpp)| &bits[pi * wpp..(pi + 1) * wpp]);
        let qp = qpair.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // SAFETY: `panel` starts a full `(kp / 2) * 16`-byte q4
            // panel and `qpair` holds n * kp / 2 pairs with
            // j0 + nr <= n — exactly each kernel's requirement.
            unsafe {
                match nr {
                    6 => k46(panel, qp, kp, j0, pm, &mut tile),
                    5 => k45(panel, qp, kp, j0, pm, &mut tile),
                    4 => k44(panel, qp, kp, j0, pm, &mut tile),
                    3 => k43(panel, qp, kp, j0, pm, &mut tile),
                    2 => k42(panel, qp, kp, j0, pm, &mut tile),
                    _ => k41(panel, qp, kp, j0, pm, &mut tile),
                }
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}
