//! AVX2+FMA microkernel: 16-row panels x 6-column register tile.
//!
//! Per k step: two 8-lane unit-stride panel loads plus one broadcast per
//! frame column feed `2 * NR` independent FMA chains — at `NR = 6` that
//! is 12 ymm accumulators + 2 panel registers + 1 broadcast register,
//! filling the 16-register ymm file (the classic GEBP shape).  The tile
//! is spilled to a 384-byte stack buffer once per full-K sweep and the
//! epilogue-fused store runs from there; at K >= 256 the spill is noise.

use core::arch::x86_64::{
    __m128i, __m256i, _mm256_add_epi32, _mm256_cvtepi8_epi16, _mm256_fmadd_ps, _mm256_loadu_ps,
    _mm256_madd_epi16, _mm256_set1_epi32, _mm256_set1_ps, _mm256_setzero_ps, _mm256_setzero_si256,
    _mm256_storeu_ps, _mm256_storeu_si256, _mm_loadu_si128,
};

use super::{store_tile, store_tile_i32};
use crate::linalg::pack::{Epilogue, PACK_MR};

/// Register-tile width (frame columns per microkernel pass).
pub(crate) const NR: usize = 6;

macro_rules! def_kern {
    ($name:ident, $nr:literal) => {
        /// # Safety
        /// Requires avx2+fma.  `panel` must hold `k * PACK_MR` floats and
        /// `x` must hold at least `(j0 + $nr) * k` floats.
        #[target_feature(enable = "avx2,fma")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const f32,
            x: *const f32,
            k: usize,
            j0: usize,
            tile: &mut [[f32; PACK_MR]; NR],
        ) {
            let mut acc0 = [_mm256_setzero_ps(); $nr];
            let mut acc1 = [_mm256_setzero_ps(); $nr];
            let mut frames = [x; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                *f = x.add((j0 + jj) * k);
            }
            for kk in 0..k {
                let a0 = _mm256_loadu_ps(panel.add(kk * PACK_MR));
                let a1 = _mm256_loadu_ps(panel.add(kk * PACK_MR + 8));
                for jj in 0..$nr {
                    let b = _mm256_set1_ps(*frames[jj].add(kk));
                    acc0[jj] = _mm256_fmadd_ps(a0, b, acc0[jj]);
                    acc1[jj] = _mm256_fmadd_ps(a1, b, acc1[jj]);
                }
            }
            for jj in 0..$nr {
                _mm256_storeu_ps(tile[jj].as_mut_ptr(), acc0[jj]);
                _mm256_storeu_ps(tile[jj].as_mut_ptr().add(8), acc1[jj]);
            }
        }
    };
}

def_kern!(kern1, 1);
def_kern!(kern2, 2);
def_kern!(kern3, 3);
def_kern!(kern4, 4);
def_kern!(kern5, 5);
def_kern!(kern6, 6);

/// `c` covers rows `crow0..` of the output; `p0..p1` is the panel range
/// to compute (full sweep: `crow0 = 0`, `p0 = 0`, `p1 = ceil(m / MR)`).
///
/// # Safety
/// Requires avx2+fma (guaranteed by the `detect()` gate in the
/// dispatcher).  Slice sizes are checked by `PackedGemm::matmul`.
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul(
    panels: &[f32],
    c: &mut [f32],
    crow0: usize,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
    epi: &Epilogue,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(panels.len(), m.div_ceil(PACK_MR) * PACK_MR * k);
    let mut tile = [[0f32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = panels[pi * PACK_MR * k..].as_ptr();
        let xp = x.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            match nr {
                6 => kern6(panel, xp, k, j0, &mut tile),
                5 => kern5(panel, xp, k, j0, &mut tile),
                4 => kern4(panel, xp, k, j0, &mut tile),
                3 => kern3(panel, xp, k, j0, &mut tile),
                2 => kern2(panel, xp, k, j0, &mut tile),
                _ => kern1(panel, xp, k, j0, &mut tile),
            }
            store_tile(c, crow0, &tile, j0, nr, pi * PACK_MR, m, n, acc, None, epi);
            j0 += nr;
        }
    }
}

macro_rules! def_kern_q8q {
    ($name:ident, $nr:literal) => {
        /// q8q integer microkernel: per k-pair, the two 16-byte panel
        /// halves sign-extend to i16 (`cvtepi8_epi16`) and one
        /// `madd_epi16` against the broadcast `[x_{2g}, x_{2g+1}]` i16
        /// pair yields row-wise exact two-product i32 partial sums — 16
        /// MACs per multiply instruction, twice the f32 FMA rate, with
        /// zero saturation risk (|w|, |x| <= 127 keeps every pair sum in
        /// i32 trivially; this is why `maddubs_epi16` was rejected — its
        /// i16 pair saturation would break bit-exact kernel parity).
        ///
        /// # Safety
        /// Requires avx2.  `panel` must hold `kp * PACK_MR` bytes in the
        /// pair-interleaved q8q layout and `qpair` at least
        /// `(j0 + $nr) * kp / 2` packed pairs.
        #[target_feature(enable = "avx2")]
        #[allow(clippy::needless_range_loop, clippy::single_element_loop)]
        unsafe fn $name(
            panel: *const i8,
            qpair: *const i32,
            kp: usize,
            j0: usize,
            tile: &mut [[i32; PACK_MR]; NR],
        ) {
            let mut lo = [_mm256_setzero_si256(); $nr];
            let mut hi = [_mm256_setzero_si256(); $nr];
            let mut frames = [qpair; $nr];
            for (jj, f) in frames.iter_mut().enumerate() {
                *f = qpair.add((j0 + jj) * (kp / 2));
            }
            for g in 0..kp / 2 {
                let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(panel.add(g * 32) as *const __m128i));
                let w1 =
                    _mm256_cvtepi8_epi16(_mm_loadu_si128(panel.add(g * 32 + 16) as *const __m128i));
                for jj in 0..$nr {
                    let b = _mm256_set1_epi32(*frames[jj].add(g));
                    lo[jj] = _mm256_add_epi32(lo[jj], _mm256_madd_epi16(w0, b));
                    hi[jj] = _mm256_add_epi32(hi[jj], _mm256_madd_epi16(w1, b));
                }
            }
            for jj in 0..$nr {
                _mm256_storeu_si256(tile[jj].as_mut_ptr() as *mut __m256i, lo[jj]);
                _mm256_storeu_si256(tile[jj].as_mut_ptr().add(8) as *mut __m256i, hi[jj]);
            }
        }
    };
}

def_kern_q8q!(kq1, 1);
def_kern_q8q!(kq2, 2);
def_kern_q8q!(kq3, 3);
def_kern_q8q!(kq4, 4);
def_kern_q8q!(kq5, 5);
def_kern_q8q!(kq6, 6);

/// q8q integer GEMM over pair-interleaved panels; same panel-range /
/// sub-slice contract as [`matmul`], writing raw i32 accumulators.
///
/// # Safety
/// Requires avx2 (guaranteed by the `detect()` gate in the dispatcher).
/// Slice sizes are checked by `PackedQuantGemm::matmul_q8q`.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_q8q(
    qpanels: &[i8],
    c32: &mut [i32],
    crow0: usize,
    qpair: &[i32],
    m: usize,
    kp: usize,
    n: usize,
    p0: usize,
    p1: usize,
) {
    debug_assert_eq!(qpanels.len(), m.div_ceil(PACK_MR) * PACK_MR * kp);
    let mut tile = [[0i32; PACK_MR]; NR];
    for pi in p0..p1 {
        let panel = qpanels[pi * PACK_MR * kp..].as_ptr();
        let qp = qpair.as_ptr();
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            match nr {
                6 => kq6(panel, qp, kp, j0, &mut tile),
                5 => kq5(panel, qp, kp, j0, &mut tile),
                4 => kq4(panel, qp, kp, j0, &mut tile),
                3 => kq3(panel, qp, kp, j0, &mut tile),
                2 => kq2(panel, qp, kp, j0, &mut tile),
                _ => kq1(panel, qp, kp, j0, &mut tile),
            }
            store_tile_i32(c32, crow0, &tile, j0, nr, pi * PACK_MR, m, n);
            j0 += nr;
        }
    }
}
