//! Packed-weight GEMM with runtime SIMD dispatch and a fused epilogue.
//!
//! The paper's entire speedup comes from amortizing weight DRAM traffic
//! across `T` time steps (Eq. 4): one `[M, K] @ [K, T]` gate GEMM per
//! block.  This module makes that GEMM stream-friendly:
//!
//! * **Panel packing** ([`PackedMatrix`]): the weight matrix is repacked
//!   **once at engine construction** into `PACK_MR`-row panels stored
//!   k-major, so the microkernel reads weights with unit stride across
//!   the whole K sweep — sequential hardware prefetch, one TLB walk per
//!   page, and SIMD lanes that map directly onto output rows (no
//!   horizontal reductions anywhere).
//! * **Runtime dispatch** ([`super::kernels`]): AVX2+FMA and NEON
//!   intrinsic microkernels selected once per process, with the portable
//!   kernel as fallback and correctness oracle.
//! * **Fused epilogue** ([`Epilogue`]): per-row bias and the gate
//!   activations are applied to the register tile as it is stored,
//!   eliminating the separate `add_row_bias` pass and the activation
//!   pass over the `[3H, T]` / `[4H, T]` gate matrix.
//! * **Calibrated crossover**: a tiny one-shot probe at construction
//!   times the packed kernel against the row-major multi-dot
//!   ([`gemm_bt`]) at small `N` and records the per-`(M, K)` crossover,
//!   replacing the old hardcoded `SMALL_N_CUTOFF = 8` guess.
//!
//! `B` operands are **time-major frames** `[N, K]` — the engines'
//! natural input layout — so the old `[T, D] -> [D, T]` transpose
//! disappears from the hot path entirely; the microkernel broadcasts
//! from at most `NR` sequential frame streams instead.
//!
//! Large GEMMs additionally fan out across the process worker pool
//! ([`super::pool`]): the `M` dimension splits at `PACK_MR` (panel)
//! granularity with panel-level work stealing, each core streaming its
//! own disjoint weight panels while sharing the `X` frames through the
//! LLC.  Row partitioning never reorders any per-element reduction, so
//! multicore results are bit-identical to the single-thread sweep.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::linalg::fastmath::{fast_sigmoid, fast_tanh};
use crate::linalg::gemm::{gemm_bt, gemm_bt_acc};
use crate::linalg::kernels::{self, Simd};
use crate::linalg::pool::{self, SendPtr, PAR_MIN_WORK};

/// Panel height: rows of `A` interleaved per packed panel.  Shared by
/// every kernel family (AVX2 reads it as 2 x 8 lanes, NEON as 4 x 4).
pub const PACK_MR: usize = 16;

/// Activation applied per output element by the fused epilogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Ident,
    Sigmoid,
    Tanh,
}

impl Act {
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::Ident => v,
            Act::Sigmoid => fast_sigmoid(v),
            Act::Tanh => fast_tanh(v),
        }
    }
}

/// Fused GEMM epilogue: applied to each output element as the register
/// tile is stored, so bias + activation cost no extra pass over `C`.
///
/// `acts` partitions the `M` rows into `acts.len()` equal segments (the
/// stacked-gate layout every engine uses: `[xhat; f; r]`, `[f; i; o;
/// chat]`, ...); an empty slice means identity everywhere.
#[derive(Debug, Clone, Copy, Default)]
pub struct Epilogue<'a> {
    /// Per-row bias (`len == m`), added before the activation.
    pub bias: Option<&'a [f32]>,
    /// Per-row-segment activations (uniform segments; empty = identity).
    pub acts: &'a [Act],
}

impl<'a> Epilogue<'a> {
    /// No bias, no activation (plain GEMM semantics).
    pub const NONE: Epilogue<'static> = Epilogue { bias: None, acts: &[] };

    /// Bias only (used where a recurrent term accumulates afterwards,
    /// e.g. LSTM's `U @ h`, so activations cannot be fused).
    pub fn with_bias(bias: &'a [f32]) -> Self {
        Self { bias: Some(bias), acts: &[] }
    }

    /// Bias + per-segment gate activations — the full fusion.
    pub fn fused(bias: &'a [f32], acts: &'a [Act]) -> Self {
        Self { bias: Some(bias), acts }
    }

    #[inline]
    pub(crate) fn act_for_row(&self, m: usize, row: usize) -> Act {
        if self.acts.is_empty() {
            Act::Ident
        } else {
            debug_assert_eq!(m % self.acts.len(), 0, "rows must split into equal act segments");
            self.acts[row * self.acts.len() / m]
        }
    }
}

/// Repack a row-major `[m, k]` matrix into `ceil(m / PACK_MR)` panels;
/// within a panel the `PACK_MR` rows are interleaved k-major, so a
/// kernel sweeping `kk` reads the panel with unit stride.  Rows past `m`
/// are zero padding (computed by the kernels, never stored).
fn pack_panels<T: Copy + Default>(a: &[T], m: usize, k: usize) -> Vec<T> {
    assert_eq!(a.len(), m * k, "pack: A must be [m, k]");
    let np = m.div_ceil(PACK_MR);
    let mut out = vec![T::default(); np * PACK_MR * k];
    for pi in 0..np {
        let base = pi * PACK_MR * k;
        for kk in 0..k {
            for r in 0..PACK_MR {
                let row = pi * PACK_MR + r;
                if row < m {
                    out[base + kk * PACK_MR + r] = a[row * k + kk];
                }
            }
        }
    }
    out
}

/// A weight matrix in panel-major packed layout (see [`pack_panels`]).
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    m: usize,
    k: usize,
    data: Vec<f32>,
}

impl PackedMatrix {
    pub fn pack(a: &[f32], m: usize, k: usize) -> Self {
        Self { m, k, data: pack_panels(a, m, k) }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The packed panel storage (including zero-padded rows).
    pub fn panels(&self) -> &[f32] {
        &self.data
    }
}

/// Matrices smaller than this skip the construction probe: the packed
/// path is used unconditionally (at these sizes everything is cache
/// resident and the probe would measure noise).
const PROBE_MIN_ELEMS: usize = 1 << 18;
const PROBE_REPS: usize = 3;

fn time_min(reps: usize, mut f: impl FnMut()) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_nanos() as u64);
    }
    best
}

/// One-shot construction-time probe: times the packed kernel against the
/// row-major multi-dot (`gemm_bt`) at `n = 1, 2, 4, 8` and returns the
/// largest prefix where the multi-dot wins **decisively** (by more than
/// `PROBE_MARGIN_PCT`).  Usually 0 on SIMD hosts — the packed kernel
/// streams weights with unit stride at every `n`.
///
/// Trade-off, documented deliberately: a wall-clock probe makes the
/// selected path (and thus low-order float rounding at `n <= 8`)
/// host-load-dependent.  The decisive margin + min-of-reps timing keeps
/// flips to cases where the multi-dot is genuinely faster; results on
/// either path stay within every parity tolerance (both are exact dot
/// products modulo summation order — see `packed_gemm_parity.rs`).
fn probe_bt_cutoff(a: &[f32], packed: &PackedMatrix, simd: Simd) -> usize {
    const PROBE_MARGIN_PCT: u64 = 10;
    let (m, k) = (packed.m, packed.k);
    let mut x = vec![0.0f32; 8 * k];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i % 17) as f32 - 8.0) * 0.125;
    }
    let mut c = vec![0.0f32; m * 8];
    let mut cutoff = 0;
    for n in [1usize, 2, 4, 8] {
        let t_bt = time_min(PROBE_REPS, || {
            gemm_bt(&mut c[..m * n], a, &x[..n * k], m, k, n);
        });
        let t_pk = time_min(PROBE_REPS, || {
            kernels::matmul(
                simd,
                packed.panels(),
                &mut c[..m * n],
                &x[..n * k],
                m,
                k,
                n,
                false,
                &Epilogue::NONE,
            );
        });
        // The multi-dot must beat the packed kernel by > the margin.
        if t_bt.saturating_mul(100 + PROBE_MARGIN_PCT) < t_pk.saturating_mul(100) {
            cutoff = n;
        } else {
            break;
        }
    }
    cutoff
}

/// Process-wide cache of probed crossovers, keyed by `(m, k)` shape.
///
/// The probe is a wall-clock measurement, so per-instance probing would
/// (a) race its timing against concurrent worker threads and (b) let two
/// engines of the same shape calibrate to *different* crossovers — a
/// nondeterminism parity tests cannot tolerate.  Instead the first
/// construction of a shape probes **under the lock** (construction-time
/// only, never on a hot path) and every later construction — from any
/// thread — reads the cached value.
fn cached_bt_cutoff(a: &[f32], packed: &PackedMatrix, simd: Simd) -> usize {
    static CACHE: OnceLock<Mutex<BTreeMap<(usize, usize), usize>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut map = cache.lock().unwrap();
    *map.entry((packed.m, packed.k))
        .or_insert_with(|| probe_bt_cutoff(a, packed, simd))
}

/// Fan one GEMM's output rows out across the process pool at `PACK_MR`
/// (panel) granularity: `kernel(csub, row0, pi)` computes panel `pi`
/// (absolute first row `row0`) into `csub`, its disjoint row sub-slice
/// of `c`.  Returns `false` — leaving `c` untouched — when the call
/// should stay serial (too little work, single-thread pool, or already
/// inside a pool task).  Shared by the f32 and int8 matmuls so the
/// guard chain and the unsafe row partitioning exist exactly once.
fn par_split_rows(
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    kernel: impl Fn(&mut [f32], usize, usize) + Sync,
) -> bool {
    let np = m.div_ceil(PACK_MR);
    if np < 2 || m * k * n < PAR_MIN_WORK || pool::in_worker() || pool::threads_hint() <= 1 {
        return false;
    }
    let p = pool::current();
    if p.threads() <= 1 {
        return false;
    }
    let cbase = SendPtr(c.as_mut_ptr());
    p.run(np, |pi| {
        let row0 = pi * PACK_MR;
        let rows = PACK_MR.min(m - row0);
        // SAFETY: panel `pi` owns exactly output rows [row0, row0+rows)
        // — a contiguous region of `c` disjoint from every other task's
        // — and the pool joins all tasks before this function returns.
        let csub = unsafe { std::slice::from_raw_parts_mut(cbase.get().add(row0 * n), rows * n) };
        kernel(csub, row0, pi);
    });
    true
}

/// An engine's handle to one packed weight matrix: owns the panels, the
/// dispatched SIMD level and the calibrated small-`N` crossover.  Packing
/// and probing happen once at engine construction; `matmul` is
/// allocation-free.
#[derive(Debug, Clone)]
pub struct PackedGemm {
    packed: PackedMatrix,
    simd: Simd,
    /// `n <= bt_cutoff` uses the retained row-major multi-dot path.
    bt_cutoff: usize,
    /// Row-major copy, retained only when the probe found a crossover.
    row_major: Option<Vec<f32>>,
}

impl PackedGemm {
    /// Pack `a[m, k]`, detect the SIMD level and calibrate the crossover.
    pub fn new(a: &[f32], m: usize, k: usize) -> Self {
        let simd = kernels::detect();
        let packed = PackedMatrix::pack(a, m, k);
        let bt_cutoff = if m * k >= PROBE_MIN_ELEMS {
            cached_bt_cutoff(a, &packed, simd)
        } else {
            0
        };
        let row_major = (bt_cutoff > 0).then(|| a.to_vec());
        Self { packed, simd, bt_cutoff, row_major }
    }

    /// Bypass probing: fixed SIMD level and crossover.  Used by the
    /// parity tests (forcing the portable oracle) and the benches.
    ///
    /// Soundness: an intrinsic level may only be requested when it is
    /// the one [`kernels::detect`] verified on this host — asserted here
    /// so safe callers can never reach an unsupported instruction set.
    pub fn with_dispatch(a: &[f32], m: usize, k: usize, simd: Simd, bt_cutoff: usize) -> Self {
        assert!(
            simd == Simd::Portable || simd == kernels::detect(),
            "SIMD level {simd:?} not available on this host (detected {:?})",
            kernels::detect()
        );
        let packed = PackedMatrix::pack(a, m, k);
        let row_major = (bt_cutoff > 0).then(|| a.to_vec());
        Self { packed, simd, bt_cutoff, row_major }
    }

    pub fn m(&self) -> usize {
        self.packed.m
    }

    pub fn k(&self) -> usize {
        self.packed.k
    }

    /// Logical (unpadded) element count — the weight-traffic unit.
    pub fn weight_len(&self) -> usize {
        self.packed.m * self.packed.k
    }

    pub fn simd(&self) -> Simd {
        self.simd
    }

    pub fn bt_cutoff(&self) -> usize {
        self.bt_cutoff
    }

    /// Smallest `n` at which the packed-panel kernel (rather than the
    /// `gemm_bt` crossover path) is guaranteed to run.  Sub-block
    /// schedulers (the stack's wavefront) must not split a block that
    /// runs packed into pieces that would run `gemm_bt` — the two paths
    /// differ in low-order rounding, which would break the bit-exactness
    /// of multicore vs single-thread execution.
    pub fn min_packed_n(&self) -> usize {
        self.bt_cutoff + 1
    }

    /// `c[m, n] = A @ X^T` (or `+=` with `acc`), where `x` holds `n`
    /// time-major frames of length `k`.  The epilogue is fused into the
    /// store pass; with `acc` the existing `C` joins the pre-activation
    /// sum (`C = act(C_old + dot + bias)`), which is what a two-term
    /// gate GEMM (QRNN) needs.
    ///
    /// Large calls are split across the process worker pool by row
    /// panel: every core streams its own disjoint `PACK_MR`-row panels
    /// (so each weight byte still leaves DRAM once, shared through the
    /// LLC) and writes its own disjoint `C` rows.  Each output element
    /// is produced by the exact same k-ordered FMA chain as the serial
    /// sweep, so the result is **bit-identical** at any thread count.
    pub fn matmul(&self, c: &mut [f32], x: &[f32], n: usize, acc: bool, epi: &Epilogue) {
        let (m, k) = (self.packed.m, self.packed.k);
        assert_eq!(x.len(), n * k, "X must be [n={n}, k={k}]");
        assert_eq!(c.len(), m * n, "C must be [m={m}, n={n}]");
        if n == 0 {
            return;
        }
        if n <= self.bt_cutoff {
            if let Some(a) = &self.row_major {
                if acc {
                    gemm_bt_acc(c, a, x, m, k, n);
                } else {
                    gemm_bt(c, a, x, m, k, n);
                }
                apply_epilogue(c, m, n, epi);
                return;
            }
        }
        let (simd, panels) = (self.simd, self.packed.panels());
        let fanned = par_split_rows(m, k, n, c, |csub, row0, pi| {
            kernels::matmul_range(simd, panels, csub, row0, x, m, k, n, acc, epi, pi, pi + 1);
        });
        if !fanned {
            kernels::matmul(simd, panels, c, x, m, k, n, acc, epi);
        }
    }
}

/// Separate-pass epilogue for the non-fused (`gemm_bt` crossover) path.
pub(crate) fn apply_epilogue(c: &mut [f32], m: usize, n: usize, epi: &Epilogue) {
    if epi.bias.is_none() && epi.acts.is_empty() {
        return;
    }
    for r in 0..m {
        let b = epi.bias.map_or(0.0, |bias| bias[r]);
        let act = epi.act_for_row(m, r);
        for v in &mut c[r * n..(r + 1) * n] {
            *v = act.apply(*v + b);
        }
    }
}

/// Int8 twin of [`PackedGemm`] for the quantized engine: the same panel
/// layout with `i8` elements, so weight bytes stream at 1/4 the f32
/// traffic; the per-row dequantization scale is fused into the store
/// epilogue together with bias and activation.  Portable kernel only for
/// now — an int8 intrinsic path (e.g. AVX2 `maddubs` / NEON `sdot`) is
/// future work.
#[derive(Debug, Clone)]
pub struct PackedQuantGemm {
    m: usize,
    k: usize,
    panels: Vec<i8>,
    scales: Vec<f32>,
}

impl PackedQuantGemm {
    pub fn new(q: &[i8], scales: &[f32], m: usize, k: usize) -> Self {
        assert_eq!(scales.len(), m, "one dequant scale per row");
        Self {
            m,
            k,
            panels: pack_panels(q, m, k),
            scales: scales.to_vec(),
        }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Weight bytes (the DRAM-traffic unit): 1 byte per logical element
    /// plus the f32 scales (padding rows are never fetched usefully).
    pub fn weight_bytes(&self) -> usize {
        self.m * self.k + self.scales.len() * 4
    }

    /// Reconstruct the dequantized f32 value at `(r, c)` straight from
    /// the panel layout (error analysis / tests — engines keep no second
    /// row-major copy of the quantized weights).
    pub fn dequant(&self, r: usize, c: usize) -> f32 {
        assert!(r < self.m && c < self.k);
        let (pi, rr) = (r / PACK_MR, r % PACK_MR);
        f32::from(self.panels[pi * PACK_MR * self.k + c * PACK_MR + rr]) * self.scales[r]
    }

    /// Same contract as [`PackedGemm::matmul`], with the row scale
    /// applied before bias/activation: `C = act(dot * scale + bias)`.
    /// Splits across the worker pool by row panel exactly like the f32
    /// path (disjoint rows, bit-identical at any thread count).
    pub fn matmul(&self, c: &mut [f32], x: &[f32], n: usize, acc: bool, epi: &Epilogue) {
        let (m, k) = (self.m, self.k);
        assert_eq!(x.len(), n * k, "X must be [n={n}, k={k}]");
        assert_eq!(c.len(), m * n, "C must be [m={m}, n={n}]");
        if n == 0 {
            return;
        }
        let (panels, scales) = (self.panels.as_slice(), self.scales.as_slice());
        let fanned = par_split_rows(m, k, n, c, |csub, row0, pi| {
            kernels::portable::matmul_quant(
                panels, scales, csub, row0, x, m, k, n, acc, epi, pi, pi + 1,
            );
        });
        if !fanned {
            let np = m.div_ceil(PACK_MR);
            kernels::portable::matmul_quant(panels, scales, c, 0, x, m, k, n, acc, epi, 0, np);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_naive;
    use crate::util::Rng;

    fn frames_to_cols(x: &[f32], n: usize, k: usize) -> Vec<f32> {
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for kk in 0..k {
                b[kk * n + j] = x[j * k + kk];
            }
        }
        b
    }

    #[test]
    fn pack_layout_is_kmajor_with_zero_padding() {
        let (m, k) = (PACK_MR + 3, 5);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
        let p = PackedMatrix::pack(&a, m, k);
        assert_eq!(p.panels().len(), 2 * PACK_MR * k);
        // Panel 0, kk = 2, row 1 == a[1][2].
        assert_eq!(p.panels()[2 * PACK_MR + 1], a[k + 2]);
        // Panel 1 holds rows 16..19; rows 19.. are zero padding.
        assert_eq!(p.panels()[PACK_MR * k + 2], a[PACK_MR * k + 2 * k]);
        for kk in 0..k {
            for r in 3..PACK_MR {
                assert_eq!(p.panels()[PACK_MR * k + kk * PACK_MR + r], 0.0);
            }
        }
    }

    #[test]
    fn act_segments_map_rows() {
        let acts = [Act::Ident, Act::Sigmoid, Act::Tanh];
        let epi = Epilogue { bias: None, acts: &acts };
        assert_eq!(epi.act_for_row(12, 0), Act::Ident);
        assert_eq!(epi.act_for_row(12, 3), Act::Ident);
        assert_eq!(epi.act_for_row(12, 4), Act::Sigmoid);
        assert_eq!(epi.act_for_row(12, 11), Act::Tanh);
        assert_eq!(Epilogue::NONE.act_for_row(12, 7), Act::Ident);
    }

    #[test]
    fn portable_matches_naive_with_epilogue() {
        let mut rng = Rng::new(7);
        let (m, k, n) = (48, 33, 5);
        let mut a = vec![0.0; m * k];
        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut x, 1.0);
        let bias: Vec<f32> = (0..m).map(|r| r as f32 * 0.01).collect();
        let acts = [Act::Ident, Act::Sigmoid, Act::Tanh];

        let pg = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 0);
        let mut got = vec![0.0; m * n];
        pg.matmul(&mut got, &x, n, false, &Epilogue::fused(&bias, &acts));

        let b = frames_to_cols(&x, n, k);
        let mut want = vec![0.0; m * n];
        gemm_naive(&mut want, &a, &b, m, k, n);
        apply_epilogue(&mut want, m, n, &Epilogue::fused(&bias, &acts));
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "idx {i}: {g} vs {w}");
        }
    }

    #[test]
    fn accumulate_joins_preactivation_sum() {
        // acc mode must apply act(C_old + dot + bias) — the QRNN contract.
        let mut rng = Rng::new(9);
        let (m, k, n) = (PACK_MR, 17, 3);
        let mut a = vec![0.0; m * k];
        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut x, 1.0);
        let bias = vec![0.25f32; m];
        let acts = [Act::Tanh];

        let pg = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 0);
        let mut got = vec![0.5f32; m * n];
        pg.matmul(&mut got, &x, n, true, &Epilogue::fused(&bias, &acts));

        let b = frames_to_cols(&x, n, k);
        let mut want = vec![0.0; m * n];
        gemm_naive(&mut want, &a, &b, m, k, n);
        for w in want.iter_mut() {
            *w = fast_tanh(*w + 0.5 + 0.25);
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn quant_panels_match_f32_reference() {
        let (m, k, n) = (24, 19, 6);
        let mut rng = Rng::new(3);
        let mut a = vec![0.0; m * k];
        rng.fill_normal(&mut a, 0.1);
        // Quantize per row, then compare against the dequantized f32 GEMM.
        let mut q = vec![0i8; m * k];
        let mut scales = vec![0.0f32; m];
        for r in 0..m {
            let row = &a[r * k..(r + 1) * k];
            let max = row.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
            let s = if max > 0.0 { max / 127.0 } else { 1.0 };
            scales[r] = s;
            for (dst, &v) in q[r * k..(r + 1) * k].iter_mut().zip(row) {
                *dst = (v / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
        let deq: Vec<f32> = (0..m * k).map(|i| f32::from(q[i]) * scales[i / k]).collect();

        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut x, 1.0);
        let pq = PackedQuantGemm::new(&q, &scales, m, k);
        let mut got = vec![0.0; m * n];
        pq.matmul(&mut got, &x, n, false, &Epilogue::NONE);

        let b = frames_to_cols(&x, n, k);
        let mut want = vec![0.0; m * n];
        gemm_naive(&mut want, &deq, &b, m, k, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn bt_crossover_path_matches_packed_path() {
        let mut rng = Rng::new(11);
        let (m, k) = (40, 65);
        let mut a = vec![0.0; m * k];
        rng.fill_normal(&mut a, 0.5);
        let bias: Vec<f32> = (0..m).map(|r| (r % 5) as f32 * 0.1).collect();
        let acts = [Act::Sigmoid];
        let packed = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 0);
        let crossed = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 8);
        for n in [1usize, 4, 8] {
            let mut x = vec![0.0; n * k];
            rng.fill_normal(&mut x, 1.0);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            packed.matmul(&mut c1, &x, n, false, &Epilogue::fused(&bias, &acts));
            crossed.matmul(&mut c2, &x, n, false, &Epilogue::fused(&bias, &acts));
            for (g, w) in c1.iter().zip(&c2) {
                assert!((g - w).abs() < 1e-4, "n={n}: {g} vs {w}");
            }
        }
    }
}
