//! Packed-weight GEMM with runtime SIMD dispatch and a fused epilogue.
//!
//! The paper's entire speedup comes from amortizing weight DRAM traffic
//! across `T` time steps (Eq. 4): one `[M, K] @ [K, T]` gate GEMM per
//! block.  This module makes that GEMM stream-friendly:
//!
//! * **Panel packing** ([`PackedMatrix`]): the weight matrix is repacked
//!   **once at engine construction** into `PACK_MR`-row panels stored
//!   k-major, so the microkernel reads weights with unit stride across
//!   the whole K sweep — sequential hardware prefetch, one TLB walk per
//!   page, and SIMD lanes that map directly onto output rows (no
//!   horizontal reductions anywhere).
//! * **Runtime dispatch** ([`super::kernels`]): intrinsic microkernels
//!   selected once per process from an ISA ladder (AVX-VNNI > AVX2+FMA
//!   on x86_64, NEON dotprod > NEON on aarch64), with the portable
//!   kernel as fallback and correctness oracle.  The integer panel
//!   layout follows the tier: the 4-way byte-dot tiers pack k-quads,
//!   the pair tiers pack k-pairs.
//! * **Fused epilogue** ([`Epilogue`]): per-row bias and the gate
//!   activations are applied to the register tile as it is stored,
//!   eliminating the separate `add_row_bias` pass and the activation
//!   pass over the `[3H, T]` / `[4H, T]` gate matrix.
//! * **Calibrated crossover**: a tiny one-shot probe at construction
//!   times the packed kernel against the row-major multi-dot
//!   ([`gemm_bt`]) at small `N` and records the per-`(M, K)` crossover,
//!   replacing the old hardcoded `SMALL_N_CUTOFF = 8` guess.
//!
//! `B` operands are **time-major frames** `[N, K]` — the engines'
//! natural input layout — so the old `[T, D] -> [D, T]` transpose
//! disappears from the hot path entirely; the microkernel broadcasts
//! from at most `NR` sequential frame streams instead.
//!
//! Large GEMMs additionally fan out across the process worker pool
//! ([`super::pool`]): the `M` dimension splits at `PACK_MR` (panel)
//! granularity with panel-level work stealing, each core streaming its
//! own disjoint weight panels while sharing the `X` frames through the
//! LLC.  Row partitioning never reorders any per-element reduction, so
//! multicore results are bit-identical to the single-thread sweep.

// On the audited unsafe allowlist (see `tools/lint` and
// `docs/UNSAFE.md`): the pool-fanned sweeps split the output (and the
// i32 accumulator) into per-panel row stripes via raw pointers; the
// disjointness argument is in each `// SAFETY:` comment and is
// re-validated structurally by `contract::check_range_output` at the
// kernel dispatch boundary.
#![allow(unsafe_code)]

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::linalg::fastmath::{fast_sigmoid, fast_tanh};
use crate::linalg::gemm::{gemm_bt, gemm_bt_acc};
use crate::linalg::kernels::{self, Simd};
use crate::linalg::pool::{self, SendPtr, PAR_MIN_WORK};

/// Panel height: rows of `A` interleaved per packed panel.  Shared by
/// every kernel family (AVX2 reads it as 2 x 8 lanes, NEON as 4 x 4).
pub const PACK_MR: usize = 16;

/// Sparse-block width along `K`: the block-sparsity bitmap
/// ([`PanelMask`]) records zero blocks of `PACK_MR x SPARSE_KB` weights,
/// and the kernels skip a whole block's k-range when its bit is clear.
/// Must stay divisible by 4 — the pair-layout integer kernels walk K in
/// pairs and chunk their loop at `SPARSE_KB / 2`, the quad-layout (dot)
/// kernels walk K in quads and chunk at `SPARSE_KB / 4`.
pub const SPARSE_KB: usize = 32;

/// Activation applied per output element by the fused epilogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Ident,
    Sigmoid,
    Tanh,
}

impl Act {
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::Ident => v,
            Act::Sigmoid => fast_sigmoid(v),
            Act::Tanh => fast_tanh(v),
        }
    }
}

/// Fused GEMM epilogue: applied to each output element as the register
/// tile is stored, so bias + activation cost no extra pass over `C`.
///
/// `acts` partitions the `M` rows into `acts.len()` equal segments (the
/// stacked-gate layout every engine uses: `[xhat; f; r]`, `[f; i; o;
/// chat]`, ...); an empty slice means identity everywhere.
#[derive(Debug, Clone, Copy, Default)]
pub struct Epilogue<'a> {
    /// Per-row bias (`len == m`), added before the activation.
    pub bias: Option<&'a [f32]>,
    /// Per-row-segment activations (uniform segments; empty = identity).
    pub acts: &'a [Act],
}

impl<'a> Epilogue<'a> {
    /// No bias, no activation (plain GEMM semantics).
    pub const NONE: Epilogue<'static> = Epilogue { bias: None, acts: &[] };

    /// Bias only (used where a recurrent term accumulates afterwards,
    /// e.g. LSTM's `U @ h`, so activations cannot be fused).
    pub fn with_bias(bias: &'a [f32]) -> Self {
        Self { bias: Some(bias), acts: &[] }
    }

    /// Bias + per-segment gate activations — the full fusion.
    pub fn fused(bias: &'a [f32], acts: &'a [Act]) -> Self {
        Self { bias: Some(bias), acts }
    }

    #[inline]
    pub(crate) fn act_for_row(&self, m: usize, row: usize) -> Act {
        if self.acts.is_empty() {
            Act::Ident
        } else {
            debug_assert_eq!(m % self.acts.len(), 0, "rows must split into equal act segments");
            self.acts[row * self.acts.len() / m]
        }
    }
}

/// Repack a row-major `[m, k]` matrix into `ceil(m / PACK_MR)` panels;
/// within a panel the `PACK_MR` rows are interleaved k-major, so a
/// kernel sweeping `kk` reads the panel with unit stride.  Rows past `m`
/// are zero padding (computed by the kernels, never stored).
fn pack_panels<T: Copy + Default>(a: &[T], m: usize, k: usize) -> Vec<T> {
    assert_eq!(a.len(), m * k, "pack: A must be [m, k]");
    let np = m.div_ceil(PACK_MR);
    let mut out = vec![T::default(); np * PACK_MR * k];
    for pi in 0..np {
        let base = pi * PACK_MR * k;
        for kk in 0..k {
            for r in 0..PACK_MR {
                let row = pi * PACK_MR + r;
                if row < m {
                    out[base + kk * PACK_MR + r] = a[row * k + kk];
                }
            }
        }
    }
    out
}

/// A weight matrix in panel-major packed layout (see [`pack_panels`]).
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    m: usize,
    k: usize,
    data: Vec<f32>,
}

impl PackedMatrix {
    pub fn pack(a: &[f32], m: usize, k: usize) -> Self {
        Self { m, k, data: pack_panels(a, m, k) }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The packed panel storage (including zero-padded rows).
    pub fn panels(&self) -> &[f32] {
        &self.data
    }
}

/// Block-sparsity bitmap over one packed matrix: one bit per
/// `PACK_MR x SPARSE_KB` weight block (panel granularity along `M`,
/// `SPARSE_KB` columns along `K`).  A **set** bit marks an *active*
/// block; a clear bit certifies that every stored weight in the block is
/// exactly zero, so the kernels skip the block's entire k-range at
/// dispatch — those weight bytes are never fetched and their
/// multiply-accumulates never issue.  Composes with every panel layout
/// (f32, q8/q8q, q4): the mask is built from the logical operand, and
/// each driver sub-slices the per-panel words next to the panel pointer.
///
/// The mask is an **exact** optimization: only blocks whose every weight
/// is literally zero (`+0.0` bit pattern for f32, `0` for int) are
/// cleared, so skipping changes no arithmetic result — the integer
/// accumulators are bit-identical by exactness, and the f32 FMA chain
/// only ever drops `+0.0 * x` terms.  Accuracy loss happens (on purpose,
/// and measurably) in the *pruning* pass that zeroes blocks
/// (`weights::prune`), never here.  Skipping is also bit-identical
/// across thread counts for free: the pool already splits work at panel
/// granularity, and the mask only removes k-chunks *within* one panel's
/// serial sweep.
#[derive(Debug, Clone)]
pub struct PanelMask {
    /// Blocks along K per panel (`ceil(k / SPARSE_KB)`).
    nkb: usize,
    /// Bitmap words per panel (`ceil(nkb / 64)`).
    words_per_panel: usize,
    /// `np * words_per_panel` words; block `kb` of panel `pi` is bit
    /// `bits[pi * words_per_panel + kb / 64] >> (kb % 64) & 1`.
    bits: Vec<u64>,
    /// Active (set) blocks over all panels.
    active: usize,
    /// Total blocks (`np * nkb`).
    total: usize,
}

impl PanelMask {
    /// Scan a row-major `[m, k]` operand and record its zero blocks.
    /// Returns `None` when every block is active, so a dense matrix
    /// carries no mask at all and takes byte-for-byte the code path it
    /// always did.
    pub fn build<T: Copy>(
        a: &[T],
        m: usize,
        k: usize,
        is_zero: impl Fn(T) -> bool,
    ) -> Option<Self> {
        assert_eq!(a.len(), m * k, "mask: A must be [m, k]");
        let np = m.div_ceil(PACK_MR);
        let nkb = k.div_ceil(SPARSE_KB);
        let words_per_panel = nkb.div_ceil(64);
        let mut bits = vec![0u64; np * words_per_panel];
        let mut active = 0usize;
        for pi in 0..np {
            let rows = PACK_MR.min(m - pi * PACK_MR);
            for kb in 0..nkb {
                let k0 = kb * SPARSE_KB;
                let k1 = (k0 + SPARSE_KB).min(k);
                let zero = (0..rows).all(|r| {
                    let row = pi * PACK_MR + r;
                    a[row * k + k0..row * k + k1].iter().all(|&v| is_zero(v))
                });
                if !zero {
                    bits[pi * words_per_panel + kb / 64] |= 1u64 << (kb % 64);
                    active += 1;
                }
            }
        }
        let total = np * nkb;
        (active < total).then_some(Self { nkb, words_per_panel, bits, active, total })
    }

    /// Mask over an f32 operand.  Only the literal `+0.0` bit pattern
    /// counts as zero — skipping a `-0.0` weight could flip a `-0.0`
    /// accumulator to `+0.0` — and the pruning pass writes `+0.0`.
    pub fn from_f32(a: &[f32], m: usize, k: usize) -> Option<Self> {
        Self::build(a, m, k, |v| v.to_bits() == 0)
    }

    /// Mask over an int8 operand (quantized weights; q8 and q4 alike).
    pub fn from_i8(q: &[i8], m: usize, k: usize) -> Option<Self> {
        Self::build(q, m, k, |v| v == 0)
    }

    /// Fraction of blocks that are active — the compute and weight
    /// traffic actually performed, relative to dense.
    pub fn density(&self) -> f64 {
        self.active as f64 / self.total as f64
    }

    pub fn active_blocks(&self) -> usize {
        self.active
    }

    pub fn total_blocks(&self) -> usize {
        self.total
    }

    pub fn blocks_per_panel(&self) -> usize {
        self.nkb
    }

    /// `(bits, words_per_panel)` in the form the kernel dispatchers
    /// consume (per-panel sub-slicing happens in the arch drivers).
    pub(crate) fn for_kernels(&self) -> (&[u64], usize) {
        (&self.bits, self.words_per_panel)
    }
}

/// Largest `K` the q8q integer path accepts: with `|w| <= 127` and
/// `|x| <= 127` per product, the i32 accumulator magnitude is bounded by
/// `K * 127 * 127`, so any `K` below this can never overflow — the
/// precondition for the "bit-identical across kernels and thread counts"
/// guarantee (integer addition is exact and associative).
pub(crate) const Q8_MAX_K: usize = (i32::MAX as usize) / (127 * 127);

/// Repack a row-major `[m, k]` int8 matrix into the q8q *pair-interleaved*
/// panel layout the integer microkernels consume.  Returns the panels and
/// `kp` (`k` rounded up to even; the pad column is zero, contributing
/// exactly 0 to every integer dot product).
///
/// Per `PACK_MR`-row panel, per k-pair `g` (`kk = 2g`), 32 bytes:
///
/// ```text
/// [ r0@kk, r0@kk+1, r1@kk, r1@kk+1, ..., r7@kk, r7@kk+1 |   (bytes 0..16)
///   r8@kk, r8@kk+1, ...,               r15@kk, r15@kk+1 ]   (bytes 16..32)
/// ```
///
/// AVX2 widens each 16-byte half to sixteen i16 lanes and feeds
/// `madd_epi16` directly (i32 lane `l` = row `l`'s two-product partial
/// sum); NEON feeds 8-byte quarters to `vmull_s8` + `vpadalq_s16` (one
/// i32 lane per row); the portable kernel indexes the same bytes
/// scalar-wise.  All three accumulate the identical exact i32 sum.
fn pack_panels_q8q(q: &[i8], m: usize, k: usize) -> (Vec<i8>, usize) {
    assert_eq!(q.len(), m * k, "pack: Q must be [m, k]");
    let kp = k.next_multiple_of(2);
    let np = m.div_ceil(PACK_MR);
    let mut out = vec![0i8; np * PACK_MR * kp];
    for pi in 0..np {
        let base = pi * PACK_MR * kp;
        for g in 0..kp / 2 {
            let kk = 2 * g;
            for r in 0..PACK_MR {
                let row = pi * PACK_MR + r;
                if row >= m {
                    continue;
                }
                let dst = base + g * 32 + (r / 8) * 16 + (r % 8) * 2;
                out[dst] = q[row * k + kk];
                if kk + 1 < k {
                    out[dst + 1] = q[row * k + kk + 1];
                }
            }
        }
    }
    (out, kp)
}

/// Largest `K` the **VNNI** q8q path accepts.  `vpdpbusd` is u8 x s8, so
/// the activations carry a +128 zero-point shift (`xu = x + 128 <= 255`)
/// and the kernel subtracts the per-row correction `128 * sum_k w` by
/// *initializing* the accumulator at `-corr`.  Any intermediate value is
/// then bounded by `K * 127 * (128 + 255)`: the correction prefix not
/// yet cancelled contributes at most `128 * |w|` per lane-k and the
/// shifted products at most `255 * |w|`.  Tighter than [`Q8_MAX_K`] by
/// ~3x; shapes past it demote to the AVX2 pair tier at construction.
pub(crate) const VNNI_Q8_MAX_K: usize = (i32::MAX as usize) / (127 * 383);

/// Repack a row-major `[m, k]` int8 matrix into the *quad-interleaved*
/// panel layout the 4-way byte-dot kernels (AVX-VNNI `vpdpbusd`, NEON
/// `sdot`) consume.  Returns the panels and `kp` (`k` rounded up to a
/// multiple of 4; pad columns are zero, contributing exactly 0 to every
/// integer dot product).
///
/// Per `PACK_MR`-row panel, per k-quad `g` (`kk = 4g`), 64 bytes,
/// row-major quads:
///
/// ```text
/// [ r0@kk..kk+4 | r1@kk..kk+4 | ... | r15@kk..kk+4 ]
/// ```
///
/// VNNI reads the group as two 32-byte ymm loads (i32 lanes = rows 0..8
/// and 8..16); sdot reads four 16-byte q loads (lane `l` of load `q` =
/// row `4q + l`).  Both broadcast one activation quad per i32 lane, so
/// each dot instruction retires 4 MACs per output row — twice the pair
/// layout's `madd_epi16` / `vmull_s8` rate.
fn pack_panels_q8q_quad(q: &[i8], m: usize, k: usize) -> (Vec<i8>, usize) {
    assert_eq!(q.len(), m * k, "pack: Q must be [m, k]");
    let kp = k.next_multiple_of(4);
    let np = m.div_ceil(PACK_MR);
    let mut out = vec![0i8; np * PACK_MR * kp];
    for pi in 0..np {
        let base = pi * PACK_MR * kp;
        for g in 0..kp / 4 {
            let kk = 4 * g;
            for r in 0..PACK_MR {
                let row = pi * PACK_MR + r;
                if row >= m {
                    continue;
                }
                for j in 0..(k - kk).min(4) {
                    out[base + g * 64 + r * 4 + j] = q[row * k + kk + j];
                }
            }
        }
    }
    (out, kp)
}

/// Per-row zero-point corrections for the VNNI u8 x s8 kernels:
/// `corr[row] = 128 * sum_k w[row, k]`, indexed by absolute packed row
/// (`np * PACK_MR` entries; padding rows stay 0).  Exactness:
/// `sum_k w * (x + 128) - 128 * sum_k w == sum_k w * x` in exact integer
/// arithmetic, and the bound check ([`VNNI_Q8_MAX_K`] /
/// [`VNNI_Q4_MAX_K`]) guarantees no intermediate wraps.  Sparse skip
/// stays consistent: a clear mask bit certifies every weight in the
/// block is zero, so skipped blocks contribute 0 to both the dot and the
/// correction sum.
fn vnni_row_corrections(q: &[i8], m: usize, k: usize) -> Vec<i32> {
    assert_eq!(q.len(), m * k, "corr: Q must be [m, k]");
    let np = m.div_ceil(PACK_MR);
    let mut corr = vec![0i32; np * PACK_MR];
    for (row, c) in corr.iter_mut().enumerate().take(m) {
        *c = 128 * q[row * k..(row + 1) * k].iter().map(|&w| i32::from(w)).sum::<i32>();
    }
    corr
}

/// Largest `K` the q4 integer path accepts: `|w| <= 7` and `|x| <= 127`
/// bound the i32 accumulator magnitude by `K * 7 * 127` — the same
/// overflow-freedom argument as [`Q8_MAX_K`], ~18x roomier.
pub(crate) const Q4_MAX_K: usize = (i32::MAX as usize) / (7 * 127);

/// Repack a row-major `[m, k]` *4-bit* matrix (values in `[-7, 7]`,
/// stored one-per-i8) into the q4 nibble-packed pair-interleaved panel
/// layout.  Per `PACK_MR`-row panel, per k-pair `g` (`kk = 2g`), **16
/// bytes**, where byte `r` carries row `r`'s two weights as signed
/// nibbles:
///
/// ```text
/// byte r = (w(r, kk) & 0x0F) | (w(r, kk + 1) << 4)      r = 0..16
/// ```
///
/// Exactly half the bytes of the q8q layout for the same shape — the
/// point of q4: the resident weight stream halves, so Eq. 4's per-block
/// DRAM amortization wins twice as hard — while keeping the same k-pair
/// step, so the integer kernels share the `qx`/`qpair` activation forms
/// with q8q unchanged.  Returns the panels and `kp` (`k` rounded up to
/// even; pad nibbles are zero, contributing exactly 0 to every dot).
fn pack_panels_q4(q: &[i8], m: usize, k: usize) -> (Vec<u8>, usize) {
    assert_eq!(q.len(), m * k, "pack: Q must be [m, k]");
    let kp = k.next_multiple_of(2);
    let np = m.div_ceil(PACK_MR);
    let mut out = vec![0u8; np * (PACK_MR / 2) * kp];
    for pi in 0..np {
        let base = pi * (PACK_MR / 2) * kp;
        for g in 0..kp / 2 {
            let kk = 2 * g;
            for r in 0..PACK_MR {
                let row = pi * PACK_MR + r;
                if row >= m {
                    continue;
                }
                let w0 = q[row * k + kk];
                let w1 = if kk + 1 < k { q[row * k + kk + 1] } else { 0 };
                debug_assert!((-7..=7).contains(&w0) && (-7..=7).contains(&w1));
                out[base + g * 16 + r] = (w0 as u8 & 0x0F) | ((w1 as u8) << 4);
            }
        }
    }
    (out, kp)
}

/// Largest `K` the VNNI q4 path accepts: same shifted-activation bound
/// as [`VNNI_Q8_MAX_K`] with `|w| <= 7` — roomy enough that real shapes
/// never demote.
pub(crate) const VNNI_Q4_MAX_K: usize = (i32::MAX as usize) / (7 * 383);

/// Row-quarter byte offsets of the VNNI quad-q4 group layout: after the
/// kernel splits a 32-byte group into sign-extended low/high nibble
/// vectors, `_mm256_unpacklo_epi8` interleaves **per 128-bit lane**, so
/// producing row-major quads for rows 0..8 in the low result (and 8..16
/// in the high one) needs rows 4..8 stored in the *upper* lane half —
/// quarters land at byte offsets 0, 16, 8, 24.  With this order the
/// kernel needs no cross-lane permute at all.
pub(crate) const VNNI_Q4_GRP_BASE: [usize; 4] = [0, 16, 8, 24];

/// Row-quarter byte offsets of the sdot quad-q4 group layout: the
/// kernel splits the group into two 16-byte halves and `vzip1q_s8` /
/// `vzip2q_s8` interleave whole halves, so the quarters are sequential.
pub(crate) const SDOT_Q4_GRP_BASE: [usize; 4] = [0, 8, 16, 24];

/// Repack a row-major `[m, k]` 4-bit matrix into the *quad-interleaved*
/// nibble layout of one byte-dot tier.  Per panel, per k-quad `g`
/// (`kk = 4g`), **32 bytes**; the quarter of rows `r / 4` starts at
/// `grp_base[r / 4]` and row `r`'s two bytes hold its four weights as
/// signed nibbles:
///
/// ```text
/// byte grp_base[r/4] + 2*(r%4) + h =
///     (w(r, kk + 2h) & 0x0F) | (w(r, kk + 2h + 1) << 4)     h = 0, 1
/// ```
///
/// `grp_base` is tier-specific ([`VNNI_Q4_GRP_BASE`] /
/// [`SDOT_Q4_GRP_BASE`]) because the two ISAs' in-register interleave
/// primitives traverse the group differently; both unpack to the exact
/// byte order of [`pack_panels_q8q_quad`] with zero shuffle cost in the
/// kernel.  Returns the panels and `kp` (`k` rounded up to a multiple
/// of 4; pad nibbles are zero).
fn pack_panels_q4_quad(q: &[i8], m: usize, k: usize, grp_base: [usize; 4]) -> (Vec<u8>, usize) {
    assert_eq!(q.len(), m * k, "pack: Q must be [m, k]");
    let kp = k.next_multiple_of(4);
    let np = m.div_ceil(PACK_MR);
    let mut out = vec![0u8; np * (PACK_MR / 2) * kp];
    for pi in 0..np {
        let base = pi * (PACK_MR / 2) * kp;
        for g in 0..kp / 4 {
            let kk = 4 * g;
            for r in 0..PACK_MR {
                let row = pi * PACK_MR + r;
                if row >= m {
                    continue;
                }
                for h in 0..2 {
                    let w0 = if kk + 2 * h < k { q[row * k + kk + 2 * h] } else { 0 };
                    let w1 = if kk + 2 * h + 1 < k { q[row * k + kk + 2 * h + 1] } else { 0 };
                    debug_assert!((-7..=7).contains(&w0) && (-7..=7).contains(&w1));
                    out[base + g * 32 + grp_base[r / 4] + 2 * (r % 4) + h] =
                        (w0 as u8 & 0x0F) | ((w1 as u8) << 4);
                }
            }
        }
    }
    (out, kp)
}

/// Caller-owned scratch for the q8q (quantized-activation) GEMM path.
///
/// Everything the dynamic quantization and the integer kernels need
/// between dispatches lives here — quantized frames, per-column scales
/// and the raw i32 accumulator block — so the hot path performs **zero
/// heap allocation** after the first dispatch at each size (buffers grow
/// once to the largest shape seen, then are reused).  Engines own one
/// and thread it through every [`PackedQuantGemm::matmul_q8q`] call.
#[derive(Debug, Clone, Default)]
pub struct QuantScratch {
    /// Quantized activation frames `[n, kp]`, i8 (zero k-padding).
    qx: Vec<i8>,
    /// AVX2 broadcast form: per frame, `kp / 2` sign-extended i16 pairs
    /// packed little-endian into one i32 each (`x_{2g} | x_{2g+1} << 16`).
    qpair: Vec<i32>,
    /// VNNI broadcast form `[n, kp]`: the same frames shifted to u8 by
    /// the +128 zero point (`qx + 128`; zero padding becomes 128, which
    /// only ever multiplies zero pad weights).  `vpdpbusd` takes its
    /// activation operand unsigned; the kernel cancels the shift with
    /// the packed per-row correction term.
    qshift: Vec<u8>,
    /// Per-column (per-time-step) symmetric dequantization scales.
    cscale: Vec<f32>,
    /// Raw `[m, n]` i32 accumulators (dequantized into `C` per panel
    /// range, so each task's stripe is still cache-hot at dequant time).
    acc: Vec<i32>,
}

impl QuantScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// The per-column scales of the most recent quantization (tests /
    /// error analysis).
    pub fn col_scales(&self) -> &[f32] {
        &self.cscale
    }
}

/// Dynamically quantize `n` time-major frames of length `k` to i8 with
/// one symmetric scale per frame (= per column of the logical `B[K, N]`
/// operand): `s_j = max_kk |x[j][kk]| / 127`, `q = round(x / s_j)`.
/// An all-zero frame gets scale 1.0 (same convention as
/// [`crate::engine::QuantMatrix`]: every value quantizes to exactly 0).
fn quantize_frames(x: &[f32], n: usize, k: usize, kp: usize, scratch: &mut QuantScratch) {
    if scratch.qx.len() < n * kp {
        scratch.qx.resize(n * kp, 0);
        scratch.qpair.resize(n * (kp / 2), 0);
        scratch.qshift.resize(n * kp, 128);
    }
    if scratch.cscale.len() < n {
        scratch.cscale.resize(n, 0.0);
    }
    for j in 0..n {
        let frame = &x[j * k..(j + 1) * k];
        let max = frame.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let s = if max > 0.0 { max / 127.0 } else { 1.0 };
        scratch.cscale[j] = s;
        let q = &mut scratch.qx[j * kp..(j + 1) * kp];
        for (dst, &v) in q.iter_mut().zip(frame) {
            *dst = (v / s).round().clamp(-127.0, 127.0) as i8;
        }
        q[k..].fill(0);
        let pairs = &mut scratch.qpair[j * (kp / 2)..(j + 1) * (kp / 2)];
        for (g, p) in pairs.iter_mut().enumerate() {
            let x0 = q[2 * g] as i16 as u16 as u32;
            let x1 = q[2 * g + 1] as i16 as u16 as u32;
            *p = (x0 | (x1 << 16)) as i32;
        }
        let shifts = &mut scratch.qshift[j * kp..(j + 1) * kp];
        for (s, &v) in shifts.iter_mut().zip(q.iter()) {
            *s = (v as u8).wrapping_add(128);
        }
    }
}

/// Dequantize a row stripe of raw i32 accumulators into `C`, fusing the
/// whole epilogue: `C = act(acc_i32 * row_scale * col_scale + bias
/// (+ C_old if acc))`.  This is the **only** place q8q integer results
/// meet f32 — shared by every kernel family and both the serial and the
/// pool-fanned sweeps, so the f32 rounding sequence is identical
/// everywhere and bit-exact parity reduces to exact i32 equality.
#[allow(clippy::too_many_arguments)]
fn dequant_rows(
    c: &mut [f32],
    crow0: usize,
    c32: &[i32],
    rows: usize,
    m: usize,
    n: usize,
    acc_mode: bool,
    row_scales: &[f32],
    col_scales: &[f32],
    epi: &Epilogue,
) {
    for rl in 0..rows {
        let row = crow0 + rl;
        let s = row_scales[row];
        let b = epi.bias.map_or(0.0, |bias| bias[row]);
        let act = epi.act_for_row(m, row);
        let src = &c32[rl * n..(rl + 1) * n];
        let dst = &mut c[rl * n..(rl + 1) * n];
        for ((cv, &av), &cs) in dst.iter_mut().zip(src).zip(&col_scales[..n]) {
            let mut v = av as f32 * (s * cs) + b;
            if acc_mode {
                v += *cv;
            }
            *cv = act.apply(v);
        }
    }
}

/// Matrices smaller than this skip the construction probe: the packed
/// path is used unconditionally (at these sizes everything is cache
/// resident and the probe would measure noise).
const PROBE_MIN_ELEMS: usize = 1 << 18;
const PROBE_REPS: usize = 3;

fn time_min(reps: usize, mut f: impl FnMut()) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_nanos() as u64);
    }
    best
}

/// One-shot construction-time probe: times the packed kernel against the
/// row-major multi-dot (`gemm_bt`) at `n = 1, 2, 4, 8` and returns the
/// largest prefix where the multi-dot wins **decisively** (by more than
/// `PROBE_MARGIN_PCT`).  Usually 0 on SIMD hosts — the packed kernel
/// streams weights with unit stride at every `n`.
///
/// Trade-off, documented deliberately: a wall-clock probe makes the
/// selected path (and thus low-order float rounding at `n <= 8`)
/// host-load-dependent.  The decisive margin + min-of-reps timing keeps
/// flips to cases where the multi-dot is genuinely faster; results on
/// either path stay within every parity tolerance (both are exact dot
/// products modulo summation order — see `packed_gemm_parity.rs`).
fn probe_bt_cutoff(a: &[f32], packed: &PackedMatrix, simd: Simd) -> usize {
    const PROBE_MARGIN_PCT: u64 = 10;
    let (m, k) = (packed.m, packed.k);
    let mut x = vec![0.0f32; 8 * k];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i % 17) as f32 - 8.0) * 0.125;
    }
    let mut c = vec![0.0f32; m * 8];
    let mut cutoff = 0;
    for n in [1usize, 2, 4, 8] {
        let t_bt = time_min(PROBE_REPS, || {
            gemm_bt(&mut c[..m * n], a, &x[..n * k], m, k, n);
        });
        let t_pk = time_min(PROBE_REPS, || {
            kernels::matmul(
                simd,
                packed.panels(),
                &mut c[..m * n],
                &x[..n * k],
                m,
                k,
                n,
                false,
                &Epilogue::NONE,
                None,
            );
        });
        // The multi-dot must beat the packed kernel by > the margin.
        if t_bt.saturating_mul(100 + PROBE_MARGIN_PCT) < t_pk.saturating_mul(100) {
            cutoff = n;
        } else {
            break;
        }
    }
    cutoff
}

/// Which crossover a registry entry calibrates: the f32
/// packed-vs-`gemm_bt` probe, or the integer-vs-widening probe of one
/// of the quantized precisions.  Part of the registry key, so one
/// `(m, k)` shape carries an independent cutoff per precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum ProbeKind {
    BtF32,
    IntQ8q,
    IntQ4,
    /// q8q on a 4-way byte-dot tier (VNNI / sdot): the quad kernels have
    /// a different integer-vs-widening crossover than the pair kernels,
    /// so they calibrate their own registry rows.
    IntQ8qDot,
    IntQ4Dot,
}

/// Process-wide registry of probed crossovers, keyed by `(kind, m, k)`.
///
/// The probe is a wall-clock measurement, so per-instance probing would
/// (a) race its timing against concurrent worker threads and (b) let two
/// engines of the same shape calibrate to *different* crossovers — a
/// nondeterminism parity tests cannot tolerate.  Instead the first
/// construction of a `(kind, shape)` probes **under the lock**
/// (construction-time only, never on a hot path) and every later
/// construction — from any thread — reads the cached value.  One
/// registry for all probe kinds makes "measured once per shape per
/// precision" a structural property instead of a convention spread over
/// per-call-site statics.
fn cached_cutoff(kind: ProbeKind, m: usize, k: usize, probe: impl FnOnce() -> usize) -> usize {
    static CACHE: OnceLock<Mutex<BTreeMap<(ProbeKind, usize, usize), usize>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut map = cache.lock().unwrap();
    *map.entry((kind, m, k)).or_insert_with(probe)
}

fn cached_bt_cutoff(a: &[f32], packed: &PackedMatrix, simd: Simd) -> usize {
    cached_cutoff(ProbeKind::BtF32, packed.m, packed.k, || {
        probe_bt_cutoff(a, packed, simd)
    })
}

/// Fan one GEMM's output rows out across the process pool at `PACK_MR`
/// (panel) granularity: `kernel(csub, row0, pi)` computes panel `pi`
/// (absolute first row `row0`) into `csub`, its disjoint row sub-slice
/// of `c`.  Returns `false` — leaving `c` untouched — when the call
/// should stay serial (too little work, single-thread pool, or already
/// inside a pool task).  Shared by the f32 and int8 matmuls so the
/// guard chain and the unsafe row partitioning exist exactly once.
fn par_split_rows(
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    kernel: impl Fn(&mut [f32], usize, usize) + Sync,
) -> bool {
    let np = m.div_ceil(PACK_MR);
    if np < 2 || m * k * n < PAR_MIN_WORK || pool::in_worker() || pool::threads_hint() <= 1 {
        return false;
    }
    let p = pool::current();
    if p.threads() <= 1 {
        return false;
    }
    let cbase = SendPtr(c.as_mut_ptr());
    p.run(np, |pi| {
        let row0 = pi * PACK_MR;
        let rows = PACK_MR.min(m - row0);
        // SAFETY: panel `pi` owns exactly output rows [row0, row0+rows)
        // — a contiguous region of `c` disjoint from every other task's
        // — and the pool joins all tasks before this function returns.
        let csub = unsafe { std::slice::from_raw_parts_mut(cbase.get().add(row0 * n), rows * n) };
        kernel(csub, row0, pi);
    });
    true
}

/// An engine's handle to one packed weight matrix: owns the panels, the
/// dispatched SIMD level and the calibrated small-`N` crossover.  Packing
/// and probing happen once at engine construction; `matmul` is
/// allocation-free.
#[derive(Debug, Clone)]
pub struct PackedGemm {
    packed: PackedMatrix,
    simd: Simd,
    /// `n <= bt_cutoff` uses the retained row-major multi-dot path.
    bt_cutoff: usize,
    /// Row-major copy, retained only when the probe found a crossover.
    row_major: Option<Vec<f32>>,
    /// Block-sparsity bitmap, auto-detected at pack time (`None` =
    /// fully dense; see [`PanelMask`]).
    mask: Option<PanelMask>,
}

impl PackedGemm {
    /// Pack `a[m, k]`, detect the SIMD level and calibrate the crossover.
    pub fn new(a: &[f32], m: usize, k: usize) -> Self {
        let simd = kernels::detect();
        let packed = PackedMatrix::pack(a, m, k);
        let bt_cutoff = if m * k >= PROBE_MIN_ELEMS {
            cached_bt_cutoff(a, &packed, simd)
        } else {
            0
        };
        let row_major = (bt_cutoff > 0).then(|| a.to_vec());
        let mask = PanelMask::from_f32(a, m, k);
        Self { packed, simd, bt_cutoff, row_major, mask }
    }

    /// Bypass probing: fixed SIMD level and crossover.  Used by the
    /// parity tests (forcing the portable oracle or a lower rung of the
    /// detected ladder) and the benches.
    ///
    /// Soundness: an intrinsic level may only be requested when the
    /// detected tier implies it runs on this host ([`Simd::runs_on`]) —
    /// asserted here so safe callers can never reach an unsupported
    /// instruction set.
    pub fn with_dispatch(a: &[f32], m: usize, k: usize, simd: Simd, bt_cutoff: usize) -> Self {
        assert!(
            simd.runs_on(kernels::detect_host()),
            "SIMD level {simd:?} not available on this host (detected {:?})",
            kernels::detect_host()
        );
        let packed = PackedMatrix::pack(a, m, k);
        let row_major = (bt_cutoff > 0).then(|| a.to_vec());
        let mask = PanelMask::from_f32(a, m, k);
        Self { packed, simd, bt_cutoff, row_major, mask }
    }

    pub fn m(&self) -> usize {
        self.packed.m
    }

    pub fn k(&self) -> usize {
        self.packed.k
    }

    /// Logical (unpadded) element count — the weight-traffic unit.
    pub fn weight_len(&self) -> usize {
        self.packed.m * self.packed.k
    }

    pub fn simd(&self) -> Simd {
        self.simd
    }

    pub fn bt_cutoff(&self) -> usize {
        self.bt_cutoff
    }

    /// Fraction of `PACK_MR x SPARSE_KB` weight blocks that are active
    /// (1.0 when dense — no mask resident at all).
    pub fn density(&self) -> f64 {
        self.mask.as_ref().map_or(1.0, PanelMask::density)
    }

    /// Drop the sparsity mask: every block computes, including the
    /// all-zero ones.  Exists for the parity tests, which assert the
    /// skip path against this dense-with-zeros sweep bitwise.
    pub fn force_dense(&mut self) {
        self.mask = None;
    }

    /// Smallest `n` at which the packed-panel kernel (rather than the
    /// `gemm_bt` crossover path) is guaranteed to run.  Sub-block
    /// schedulers (the stack's wavefront) must not split a block that
    /// runs packed into pieces that would run `gemm_bt` — the two paths
    /// differ in low-order rounding, which would break the bit-exactness
    /// of multicore vs single-thread execution.
    pub fn min_packed_n(&self) -> usize {
        self.bt_cutoff + 1
    }

    /// `c[m, n] = A @ X^T` (or `+=` with `acc`), where `x` holds `n`
    /// time-major frames of length `k`.  The epilogue is fused into the
    /// store pass; with `acc` the existing `C` joins the pre-activation
    /// sum (`C = act(C_old + dot + bias)`), which is what a two-term
    /// gate GEMM (QRNN) needs.
    ///
    /// Large calls are split across the process worker pool by row
    /// panel: every core streams its own disjoint `PACK_MR`-row panels
    /// (so each weight byte still leaves DRAM once, shared through the
    /// LLC) and writes its own disjoint `C` rows.  Each output element
    /// is produced by the exact same k-ordered FMA chain as the serial
    /// sweep, so the result is **bit-identical** at any thread count.
    pub fn matmul(&self, c: &mut [f32], x: &[f32], n: usize, acc: bool, epi: &Epilogue) {
        let (m, k) = (self.packed.m, self.packed.k);
        assert_eq!(x.len(), n * k, "X must be [n={n}, k={k}]");
        assert_eq!(c.len(), m * n, "C must be [m={m}, n={n}]");
        if n == 0 {
            return;
        }
        if n <= self.bt_cutoff {
            if let Some(a) = &self.row_major {
                if acc {
                    gemm_bt_acc(c, a, x, m, k, n);
                } else {
                    gemm_bt(c, a, x, m, k, n);
                }
                apply_epilogue(c, m, n, epi);
                return;
            }
        }
        // The gemm_bt path above ignores the mask: the multi-dot reads
        // the row-major copy directly, and its zero terms cost what they
        // always did (only ever taken at tiny n).
        let (simd, panels) = (self.simd, self.packed.panels());
        let pm_all = self.mask.as_ref().map(PanelMask::for_kernels);
        let fanned = par_split_rows(m, k, n, c, |csub, row0, pi| {
            kernels::matmul_range(simd, panels, csub, row0, x, m, k, n, acc, epi, pm_all, pi, pi + 1);
        });
        if !fanned {
            kernels::matmul(simd, panels, c, x, m, k, n, acc, epi, pm_all);
        }
    }
}

/// Separate-pass epilogue for the non-fused (`gemm_bt` crossover) path.
pub(crate) fn apply_epilogue(c: &mut [f32], m: usize, n: usize, epi: &Epilogue) {
    if epi.bias.is_none() && epi.acts.is_empty() {
        return;
    }
    for r in 0..m {
        let b = epi.bias.map_or(0.0, |bias| bias[r]);
        let act = epi.act_for_row(m, r);
        for v in &mut c[r * n..(r + 1) * n] {
            *v = act.apply(*v + b);
        }
    }
}

/// Int8 twin of [`PackedGemm`] for the quantized engines.  Two modes:
///
/// * **Weights-only (`q8`)**: int8 panels in the same k-major layout as
///   the f32 engines, each weight byte fetched once per block and
///   *widened to f32 in registers* — 1/4 the weight DRAM traffic, f32
///   arithmetic.  This is [`PackedQuantGemm::matmul`].
/// * **Quantized activations (`q8q`)**: the activation block is
///   dynamically quantized per column (per time step) to i8, the dot
///   products accumulate in **i32 integer arithmetic** end to end, and
///   f32 appears only in the fused dequant epilogue
///   (`C = act(acc * row_scale * col_scale + bias)`).  This is
///   [`PackedQuantGemm::matmul_q8q`]; kernels are runtime-dispatched
///   (AVX2 `madd_epi16` on sign-extended pairs, NEON `vmull_s8` +
///   `vpadalq_s16`, portable scalar i32) and — because integer addition
///   is exact and associative — produce **bit-identical** i32
///   accumulators on every dispatch target and at every thread count.
///
/// Why `madd_epi16` on sign-extended i8 rather than the classic
/// `maddubs_epi16` u8×i8 pairing: `maddubs` *saturates* its i16 pair
/// sums (reachable with |w|, |x| ≤ 127 once activations are offset to
/// unsigned), which would make the result depend on the kernel family —
/// the exact-parity contract above is worth the one extra widening per
/// 32 weights.
#[derive(Debug, Clone)]
pub struct PackedQuantGemm {
    m: usize,
    k: usize,
    /// k-major i8 panels (widening path).  Empty on q8q/q4 handles whose
    /// probe found `int_cutoff == 0`: the fallback is unreachable then,
    /// and dropping the copy keeps the resident footprint at one byte
    /// (q8q) / one nibble (q4) per weight.
    panels: Vec<i8>,
    /// Pair-interleaved i8 panels (q8q integer path; empty otherwise).
    qpanels: Vec<i8>,
    /// Nibble-packed panels (q4 integer path; empty otherwise).  Half
    /// the bytes of `qpanels` for the same shape.
    q4panels: Vec<u8>,
    /// `k` rounded up to the integer-panel k-group (even on the pair
    /// tiers, a multiple of 4 on the quad tiers; 0 in q8 mode).
    kp: usize,
    /// VNNI zero-point corrections `128 * sum_k w[row]`, one i32 per
    /// packed row (`np * PACK_MR`); empty on every other tier.
    corr: Vec<i32>,
    /// Block-sparsity bitmap over the quantized operand, shared by every
    /// resident panel layout (`None` = dense; see [`PanelMask`]).
    mask: Option<PanelMask>,
    scales: Vec<f32>,
    simd: Simd,
    /// `n <= int_cutoff` routes q8q/q4 calls through the widening
    /// fallback (probed at construction, like [`PackedGemm::bt_cutoff`];
    /// q4 handles store their own probe kind's value here).
    int_cutoff: usize,
}

impl PackedQuantGemm {
    /// Weights-only mode (`q8`): int8 storage, f32 compute.
    pub fn new(q: &[i8], scales: &[f32], m: usize, k: usize) -> Self {
        assert_eq!(scales.len(), m, "one dequant scale per row");
        Self {
            m,
            k,
            panels: pack_panels(q, m, k),
            qpanels: Vec::new(),
            q4panels: Vec::new(),
            kp: 0,
            corr: Vec::new(),
            mask: PanelMask::from_i8(q, m, k),
            scales: scales.to_vec(),
            simd: kernels::detect(),
            int_cutoff: 0,
        }
    }

    /// Quantized-activation mode (`q8q`): packs the integer-kernel panel
    /// layout alongside the widening one, dispatches the SIMD level once
    /// and probes the integer-vs-widening crossover (measured, not
    /// assumed — cached per `(m, k)` like the f32 probe).
    ///
    /// When the crossover comes back 0 (the usual case) the widening
    /// panels are unreachable on the hot path and are **dropped**, so
    /// the resident int8 footprint stays one copy — the point of int8 on
    /// footprint-constrained targets.  `with_dispatch_q8q` keeps both
    /// (the parity tests compare the two paths explicitly).
    pub fn new_q8q(q: &[i8], scales: &[f32], m: usize, k: usize) -> Self {
        let mut pq = Self::with_dispatch_q8q(q, scales, m, k, kernels::detect(), 0);
        if m * k >= PROBE_MIN_ELEMS {
            pq.int_cutoff = cached_int_cutoff(&pq);
        }
        if pq.int_cutoff == 0 {
            pq.panels = Vec::new();
        }
        pq
    }

    /// q8q constructor with a fixed SIMD level and crossover (parity
    /// tests and benches).  Same soundness rule as
    /// [`PackedGemm::with_dispatch`]: an intrinsic level may only be
    /// requested when [`kernels::detect`] verified it on this host.
    pub fn with_dispatch_q8q(
        q: &[i8],
        scales: &[f32],
        m: usize,
        k: usize,
        simd: Simd,
        int_cutoff: usize,
    ) -> Self {
        assert_eq!(scales.len(), m, "one dequant scale per row");
        assert!(
            simd.runs_on(kernels::detect_host()),
            "SIMD level {simd:?} not available on this host (detected {:?})",
            kernels::detect_host()
        );
        assert!(
            k <= Q8_MAX_K,
            "q8q supports K up to {Q8_MAX_K} (i32 accumulator bound), got {k}"
        );
        // The VNNI zero-point shift tightens the overflow bound; shapes
        // past it silently demote to the AVX2 pair tier (always present
        // beneath VNNI in the ladder) instead of rejecting a K every
        // other tier accepts.
        let simd = if simd == Simd::Vnni && k > VNNI_Q8_MAX_K { Simd::Avx2 } else { simd };
        let (qpanels, kp) = match simd {
            Simd::Vnni | Simd::Sdot => pack_panels_q8q_quad(q, m, k),
            _ => pack_panels_q8q(q, m, k),
        };
        let corr = if simd == Simd::Vnni { vnni_row_corrections(q, m, k) } else { Vec::new() };
        Self {
            m,
            k,
            panels: pack_panels(q, m, k),
            qpanels,
            q4panels: Vec::new(),
            kp,
            corr,
            mask: PanelMask::from_i8(q, m, k),
            scales: scales.to_vec(),
            simd,
            int_cutoff,
        }
    }

    /// q4 mode: signed 4-bit weights (values in `[-7, 7]`) packed two
    /// per byte — **exactly half the resident weight bytes of q8** for
    /// the same shape — with dynamically quantized activations and exact
    /// i32 accumulation end to end, like q8q.  One dequant scale per
    /// output row, applied by the same fused dequant epilogue
    /// ([`dequant_rows`]).  Probes its own integer-vs-widening crossover
    /// (the q4 kernel pays an in-register unpack per byte that q8q does
    /// not) and drops the widening copy when unreachable.
    pub fn new_q4(q: &[i8], scales: &[f32], m: usize, k: usize) -> Self {
        let mut pq = Self::with_dispatch_q4(q, scales, m, k, kernels::detect(), 0);
        if m * k >= PROBE_MIN_ELEMS {
            pq.int_cutoff = cached_int_cutoff(&pq);
        }
        if pq.int_cutoff == 0 {
            pq.panels = Vec::new();
        }
        pq
    }

    /// q4 constructor with a fixed SIMD level and crossover (parity
    /// tests and benches); keeps the widening panels regardless of the
    /// crossover so both paths stay comparable.  Same soundness rule as
    /// [`PackedGemm::with_dispatch`].
    pub fn with_dispatch_q4(
        q: &[i8],
        scales: &[f32],
        m: usize,
        k: usize,
        simd: Simd,
        int_cutoff: usize,
    ) -> Self {
        assert_eq!(scales.len(), m, "one dequant scale per row");
        assert!(
            simd.runs_on(kernels::detect_host()),
            "SIMD level {simd:?} not available on this host (detected {:?})",
            kernels::detect_host()
        );
        assert!(
            k <= Q4_MAX_K,
            "q4 supports K up to {Q4_MAX_K} (i32 accumulator bound), got {k}"
        );
        assert!(
            q.iter().all(|&v| (-7..=7).contains(&v)),
            "q4 weights must lie in [-7, 7]"
        );
        // Same silent VNNI -> AVX2 demotion as q8q (the q4 bound is ~18x
        // roomier, so this is essentially unreachable in practice).
        let simd = if simd == Simd::Vnni && k > VNNI_Q4_MAX_K { Simd::Avx2 } else { simd };
        let (q4panels, kp) = match simd {
            Simd::Vnni => pack_panels_q4_quad(q, m, k, VNNI_Q4_GRP_BASE),
            Simd::Sdot => pack_panels_q4_quad(q, m, k, SDOT_Q4_GRP_BASE),
            _ => pack_panels_q4(q, m, k),
        };
        let corr = if simd == Simd::Vnni { vnni_row_corrections(q, m, k) } else { Vec::new() };
        Self {
            m,
            k,
            panels: pack_panels(q, m, k),
            qpanels: Vec::new(),
            q4panels,
            kp,
            corr,
            mask: PanelMask::from_i8(q, m, k),
            scales: scales.to_vec(),
            simd,
            int_cutoff,
        }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The dispatch tier this handle's panels were packed for (after
    /// any silent Vnni -> Avx2 exactness demotion).
    pub fn simd(&self) -> Simd {
        self.simd
    }

    /// Streamed weight panel bytes per block (the DRAM-traffic unit,
    /// before scales): one byte per logical element for q8/q8q, half a
    /// byte for q4, scaled by the block-sparse density — skipped blocks
    /// are never fetched, so their bytes never cross the bus.
    pub fn panel_weight_bytes(&self) -> usize {
        let dense = if self.is_q4() {
            (self.m * self.k).div_ceil(2)
        } else {
            self.m * self.k
        };
        match &self.mask {
            None => dense,
            Some(pm) => (dense as f64 * pm.density()).round() as usize,
        }
    }

    /// Weight bytes (the DRAM-traffic unit): streamed panel bytes plus
    /// the f32 scales (padding rows are never fetched usefully).
    pub fn weight_bytes(&self) -> usize {
        self.panel_weight_bytes() + self.scales.len() * 4
    }

    /// Reconstruct the dequantized f32 value at `(r, c)` straight from
    /// whichever panel layout is resident (error analysis / tests —
    /// engines keep no second row-major copy of the quantized weights).
    pub fn dequant(&self, r: usize, c: usize) -> f32 {
        assert!(r < self.m && c < self.k);
        let (pi, rr) = (r / PACK_MR, r % PACK_MR);
        let quad = matches!(self.simd, Simd::Vnni | Simd::Sdot);
        let q = if !self.panels.is_empty() {
            self.panels[pi * PACK_MR * self.k + c * PACK_MR + rr]
        } else if self.is_q4() {
            // q4 handle whose widening panels were dropped: decode the
            // signed nibble from whichever packed layout the tier uses.
            let b = if quad {
                let grp_base = if self.simd == Simd::Vnni {
                    VNNI_Q4_GRP_BASE
                } else {
                    SDOT_Q4_GRP_BASE
                };
                let base = pi * (PACK_MR / 2) * self.kp + (c / 4) * 32;
                self.q4panels[base + grp_base[rr / 4] + 2 * (rr % 4) + (c % 4) / 2]
            } else {
                self.q4panels[pi * (PACK_MR / 2) * self.kp + (c / 2) * 16 + rr]
            };
            if c % 2 == 0 {
                ((b << 4) as i8) >> 4
            } else {
                (b as i8) >> 4
            }
        } else if quad {
            // q8q quad layout: row-major k-quads, 64 bytes per group.
            self.qpanels[pi * PACK_MR * self.kp + (c / 4) * 64 + rr * 4 + c % 4]
        } else {
            // q8q handle whose widening panels were dropped: read the
            // pair-interleaved integer layout instead.
            let base = pi * PACK_MR * self.kp + (c / 2) * 32;
            self.qpanels[base + (rr / 8) * 16 + (rr % 8) * 2 + c % 2]
        };
        f32::from(q) * self.scales[r]
    }

    /// Whether this handle was built for an integer (quantized
    /// activation) path — q8q or q4.
    pub fn quantizes_activations(&self) -> bool {
        !self.qpanels.is_empty() || !self.q4panels.is_empty()
    }

    /// Whether this handle packs 4-bit (nibble) weight panels.
    pub fn is_q4(&self) -> bool {
        !self.q4panels.is_empty()
    }

    /// Fraction of `PACK_MR x SPARSE_KB` weight blocks that are active
    /// (1.0 when dense — no mask resident at all).
    pub fn density(&self) -> f64 {
        self.mask.as_ref().map_or(1.0, PanelMask::density)
    }

    /// Drop the sparsity mask: every block computes, including the
    /// all-zero ones.  Exists for the parity tests, which assert the
    /// skip path against this dense-with-zeros sweep bitwise.
    pub fn force_dense(&mut self) {
        self.mask = None;
    }

    /// Probed integer-vs-widening crossover (`0` = integer path at every
    /// `n`).
    pub fn int_cutoff(&self) -> usize {
        self.int_cutoff
    }

    /// Smallest `n` at which the q8q integer kernel is guaranteed to run
    /// (the widening fallback below it computes different low-order
    /// numerics — sub-block schedulers must not cross this boundary).
    pub fn min_int_n(&self) -> usize {
        self.int_cutoff + 1
    }

    /// Weight-only (widening) GEMM — same contract as
    /// [`PackedGemm::matmul`], with the row scale applied before
    /// bias/activation: `C = act(dot * scale + bias)`.  Splits across
    /// the worker pool by row panel exactly like the f32 path (disjoint
    /// rows, bit-identical at any thread count).
    pub fn matmul(&self, c: &mut [f32], x: &[f32], n: usize, acc: bool, epi: &Epilogue) {
        let (m, k) = (self.m, self.k);
        assert_eq!(x.len(), n * k, "X must be [n={n}, k={k}]");
        assert_eq!(c.len(), m * n, "C must be [m={m}, n={n}]");
        assert!(
            !self.panels.is_empty(),
            "widening panels were dropped (q8q handle with int_cutoff = 0 \
             never takes this path)"
        );
        if n == 0 {
            return;
        }
        let (panels, scales) = (self.panels.as_slice(), self.scales.as_slice());
        let pm_all = self.mask.as_ref().map(PanelMask::for_kernels);
        let fanned = par_split_rows(m, k, n, c, |csub, row0, pi| {
            kernels::portable::matmul_quant(
                panels, scales, csub, row0, x, m, k, n, acc, epi, pm_all, pi, pi + 1,
            );
        });
        if !fanned {
            let np = m.div_ceil(PACK_MR);
            kernels::portable::matmul_quant(
                panels, scales, c, 0, x, m, k, n, acc, epi, pm_all, 0, np,
            );
        }
    }

    /// Quantized-activation GEMM: dynamic per-column i8 quantization of
    /// `x`, integer (i32) accumulation in the dispatched microkernel,
    /// dequant + bias + activation fused into the store.  **No f32
    /// multiply touches the inner loop.**  `scratch` is caller-owned and
    /// reused across dispatches (zero hot-path allocation after the
    /// first call at each size).
    ///
    /// `n <= int_cutoff` (probed at construction) falls back to the
    /// widening path — different low-order numerics, same tolerance
    /// class; callers that need width-invariant bits gate on
    /// [`Self::min_int_n`].  Large calls M-split across the worker pool
    /// (disjoint row panels; i32 accumulation is exact, so results stay
    /// bit-identical at any thread count).
    pub fn matmul_q8q(
        &self,
        c: &mut [f32],
        x: &[f32],
        n: usize,
        acc: bool,
        epi: &Epilogue,
        scratch: &mut QuantScratch,
    ) {
        assert!(
            self.quantizes_activations(),
            "matmul_q8q requires a PackedQuantGemm built with new_q8q or new_q4"
        );
        let (m, k) = (self.m, self.k);
        assert_eq!(x.len(), n * k, "X must be [n={n}, k={k}]");
        assert_eq!(c.len(), m * n, "C must be [m={m}, n={n}]");
        if n == 0 {
            return;
        }
        if n <= self.int_cutoff {
            self.matmul(c, x, n, acc, epi);
            return;
        }
        self.matmul_int(c, x, n, acc, epi, scratch);
    }

    /// q4 integer GEMM — same contract as [`Self::matmul_q8q`] (dynamic
    /// per-column activation quantization, exact i32 accumulation, fused
    /// dequant epilogue, widening fallback below the probed crossover),
    /// over nibble-packed panels at **half** the weight traffic.
    pub fn matmul_q4(
        &self,
        c: &mut [f32],
        x: &[f32],
        n: usize,
        acc: bool,
        epi: &Epilogue,
        scratch: &mut QuantScratch,
    ) {
        assert!(self.is_q4(), "matmul_q4 requires a PackedQuantGemm built with new_q4");
        self.matmul_q8q(c, x, n, acc, epi, scratch);
    }

    /// The integer path body (no crossover check — the probe times this
    /// directly against the widening path).
    fn matmul_int(
        &self,
        c: &mut [f32],
        x: &[f32],
        n: usize,
        acc: bool,
        epi: &Epilogue,
        scratch: &mut QuantScratch,
    ) {
        let (m, k, kp) = (self.m, self.k, self.kp);
        quantize_frames(x, n, k, kp, scratch);
        if scratch.acc.len() < m * n {
            scratch.acc.resize(m * n, 0);
        }
        let QuantScratch { qx, qpair, qshift, cscale, acc: acc32 } = scratch;
        let (qx, qpair, qshift, cscale) =
            (&qx[..n * kp], &qpair[..n * (kp / 2)], &qshift[..n * kp], &cscale[..n]);
        let (simd, scales) = (self.simd, self.scales.as_slice());
        let (qpanels, q4panels) = (self.qpanels.as_slice(), self.q4panels.as_slice());
        let corr = self.corr.as_slice();
        let q4 = self.is_q4();
        let pm_all = self.mask.as_ref().map(PanelMask::for_kernels);
        let acc_base = SendPtr(acc32.as_mut_ptr());
        let fanned = par_split_rows(m, k, n, c, |csub, row0, pi| {
            let rows = PACK_MR.min(m - row0);
            // SAFETY: panel `pi` owns i32 accumulator rows
            // [row0, row0 + rows) — disjoint from every other task's —
            // and the pool joins before `matmul_int` returns.
            let c32 =
                unsafe { std::slice::from_raw_parts_mut(acc_base.get().add(row0 * n), rows * n) };
            if q4 {
                kernels::matmul_q4(
                    simd, q4panels, c32, row0, qx, qpair, qshift, corr, m, kp, n, pm_all, pi,
                    pi + 1,
                );
            } else {
                kernels::matmul_q8q(
                    simd, qpanels, c32, row0, qx, qpair, qshift, corr, m, kp, n, pm_all, pi,
                    pi + 1,
                );
            }
            dequant_rows(csub, row0, c32, rows, m, n, acc, scales, cscale, epi);
        });
        if !fanned {
            let np = m.div_ceil(PACK_MR);
            let c32 = &mut acc32[..m * n];
            if q4 {
                kernels::matmul_q4(
                    simd, q4panels, c32, 0, qx, qpair, qshift, corr, m, kp, n, pm_all, 0, np,
                );
            } else {
                kernels::matmul_q8q(
                    simd, qpanels, c32, 0, qx, qpair, qshift, corr, m, kp, n, pm_all, 0, np,
                );
            }
            dequant_rows(c, 0, c32, m, m, n, acc, scales, cscale, epi);
        }
    }

    /// Raw integer GEMM: quantize `x` and write the exact `[m, n]` i32
    /// accumulators (no dequant, serial sweep).  The parity tests'
    /// ground truth — "bit-identical across dispatch targets" is
    /// asserted on these values directly.
    pub fn matmul_i32(&self, c32: &mut [i32], x: &[f32], n: usize, scratch: &mut QuantScratch) {
        assert!(
            self.quantizes_activations(),
            "matmul_i32 requires a PackedQuantGemm built with new_q8q or new_q4"
        );
        let (m, k, kp) = (self.m, self.k, self.kp);
        assert_eq!(x.len(), n * k, "X must be [n={n}, k={k}]");
        assert_eq!(c32.len(), m * n, "C must be [m={m}, n={n}]");
        if n == 0 {
            return;
        }
        quantize_frames(x, n, k, kp, scratch);
        let np = m.div_ceil(PACK_MR);
        let pm_all = self.mask.as_ref().map(PanelMask::for_kernels);
        let (qx, qpair, qshift) =
            (&scratch.qx[..n * kp], &scratch.qpair[..n * (kp / 2)], &scratch.qshift[..n * kp]);
        let corr = self.corr.as_slice();
        if self.is_q4() {
            kernels::matmul_q4(
                self.simd, &self.q4panels, c32, 0, qx, qpair, qshift, corr, m, kp, n, pm_all, 0, np,
            );
        } else {
            kernels::matmul_q8q(
                self.simd, &self.qpanels, c32, 0, qx, qpair, qshift, corr, m, kp, n, pm_all, 0, np,
            );
        }
    }
}

/// One-shot construction-time probe for the q8q path: times the integer
/// kernel (dynamic quantization included — it is part of every q8q
/// dispatch) against the widening fallback at `n = 1, 2, 4, 8` and
/// returns the largest prefix where widening wins decisively.  Usually 0
/// on SIMD hosts: the integer kernel does twice the multiplies per
/// instruction and streams the same byte count.
fn probe_int_cutoff(pq: &PackedQuantGemm) -> usize {
    const PROBE_MARGIN_PCT: u64 = 10;
    let (m, k) = (pq.m, pq.k);
    let mut x = vec![0.0f32; 8 * k];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i % 17) as f32 - 8.0) * 0.125;
    }
    let mut c = vec![0.0f32; m * 8];
    let mut scratch = QuantScratch::new();
    let mut cutoff = 0;
    for n in [1usize, 2, 4, 8] {
        let t_widen = time_min(PROBE_REPS, || {
            pq.matmul(&mut c[..m * n], &x[..n * k], n, false, &Epilogue::NONE);
        });
        let t_int = time_min(PROBE_REPS, || {
            pq.matmul_int(&mut c[..m * n], &x[..n * k], n, false, &Epilogue::NONE, &mut scratch);
        });
        if t_widen.saturating_mul(100 + PROBE_MARGIN_PCT) < t_int.saturating_mul(100) {
            cutoff = n;
        } else {
            break;
        }
    }
    cutoff
}

/// Registry wrapper for the integer-vs-widening probe; the handle's
/// panel layout picks the probe kind (q4 and q8q calibrate separately —
/// the q4 kernel has different unpack cost per byte).
fn cached_int_cutoff(pq: &PackedQuantGemm) -> usize {
    let dot = matches!(pq.simd, Simd::Vnni | Simd::Sdot);
    let kind = match (pq.is_q4(), dot) {
        (false, false) => ProbeKind::IntQ8q,
        (true, false) => ProbeKind::IntQ4,
        (false, true) => ProbeKind::IntQ8qDot,
        (true, true) => ProbeKind::IntQ4Dot,
    };
    cached_cutoff(kind, pq.m, pq.k, || probe_int_cutoff(pq))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_naive;
    use crate::util::Rng;

    fn frames_to_cols(x: &[f32], n: usize, k: usize) -> Vec<f32> {
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for kk in 0..k {
                b[kk * n + j] = x[j * k + kk];
            }
        }
        b
    }

    #[test]
    fn pack_layout_is_kmajor_with_zero_padding() {
        let (m, k) = (PACK_MR + 3, 5);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
        let p = PackedMatrix::pack(&a, m, k);
        assert_eq!(p.panels().len(), 2 * PACK_MR * k);
        // Panel 0, kk = 2, row 1 == a[1][2].
        assert_eq!(p.panels()[2 * PACK_MR + 1], a[k + 2]);
        // Panel 1 holds rows 16..19; rows 19.. are zero padding.
        assert_eq!(p.panels()[PACK_MR * k + 2], a[PACK_MR * k + 2 * k]);
        for kk in 0..k {
            for r in 3..PACK_MR {
                assert_eq!(p.panels()[PACK_MR * k + kk * PACK_MR + r], 0.0);
            }
        }
    }

    #[test]
    fn act_segments_map_rows() {
        let acts = [Act::Ident, Act::Sigmoid, Act::Tanh];
        let epi = Epilogue { bias: None, acts: &acts };
        assert_eq!(epi.act_for_row(12, 0), Act::Ident);
        assert_eq!(epi.act_for_row(12, 3), Act::Ident);
        assert_eq!(epi.act_for_row(12, 4), Act::Sigmoid);
        assert_eq!(epi.act_for_row(12, 11), Act::Tanh);
        assert_eq!(Epilogue::NONE.act_for_row(12, 7), Act::Ident);
    }

    #[test]
    fn portable_matches_naive_with_epilogue() {
        let mut rng = Rng::new(7);
        let (m, k, n) = (48, 33, 5);
        let mut a = vec![0.0; m * k];
        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut x, 1.0);
        let bias: Vec<f32> = (0..m).map(|r| r as f32 * 0.01).collect();
        let acts = [Act::Ident, Act::Sigmoid, Act::Tanh];

        let pg = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 0);
        let mut got = vec![0.0; m * n];
        pg.matmul(&mut got, &x, n, false, &Epilogue::fused(&bias, &acts));

        let b = frames_to_cols(&x, n, k);
        let mut want = vec![0.0; m * n];
        gemm_naive(&mut want, &a, &b, m, k, n);
        apply_epilogue(&mut want, m, n, &Epilogue::fused(&bias, &acts));
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "idx {i}: {g} vs {w}");
        }
    }

    #[test]
    fn accumulate_joins_preactivation_sum() {
        // acc mode must apply act(C_old + dot + bias) — the QRNN contract.
        let mut rng = Rng::new(9);
        let (m, k, n) = (PACK_MR, 17, 3);
        let mut a = vec![0.0; m * k];
        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut x, 1.0);
        let bias = vec![0.25f32; m];
        let acts = [Act::Tanh];

        let pg = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 0);
        let mut got = vec![0.5f32; m * n];
        pg.matmul(&mut got, &x, n, true, &Epilogue::fused(&bias, &acts));

        let b = frames_to_cols(&x, n, k);
        let mut want = vec![0.0; m * n];
        gemm_naive(&mut want, &a, &b, m, k, n);
        for w in want.iter_mut() {
            *w = fast_tanh(*w + 0.5 + 0.25);
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn quant_panels_match_f32_reference() {
        let (m, k, n) = (24, 19, 6);
        let mut rng = Rng::new(3);
        let mut a = vec![0.0; m * k];
        rng.fill_normal(&mut a, 0.1);
        // Quantize per row, then compare against the dequantized f32 GEMM.
        let mut q = vec![0i8; m * k];
        let mut scales = vec![0.0f32; m];
        for r in 0..m {
            let row = &a[r * k..(r + 1) * k];
            let max = row.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
            let s = if max > 0.0 { max / 127.0 } else { 1.0 };
            scales[r] = s;
            for (dst, &v) in q[r * k..(r + 1) * k].iter_mut().zip(row) {
                *dst = (v / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
        let deq: Vec<f32> = (0..m * k).map(|i| f32::from(q[i]) * scales[i / k]).collect();

        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut x, 1.0);
        let pq = PackedQuantGemm::new(&q, &scales, m, k);
        let mut got = vec![0.0; m * n];
        pq.matmul(&mut got, &x, n, false, &Epilogue::NONE);

        let b = frames_to_cols(&x, n, k);
        let mut want = vec![0.0; m * n];
        gemm_naive(&mut want, &deq, &b, m, k, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn q8q_panel_layout_pairs_and_padding() {
        // m = 17 rows (one full panel + 1), k = 5 (odd -> kp = 6 with a
        // zero pad column).  Check the pair-interleaved placement.
        let (m, k) = (PACK_MR + 1, 5);
        let q: Vec<i8> = (0..m * k).map(|i| (i % 127) as i8).collect();
        let (panels, kp) = pack_panels_q8q(&q, m, k);
        assert_eq!(kp, 6);
        assert_eq!(panels.len(), 2 * PACK_MR * kp);
        let at = |pi: usize, g: usize, r: usize, o: usize| {
            panels[pi * PACK_MR * kp + g * 32 + (r / 8) * 16 + (r % 8) * 2 + o]
        };
        // Panel 0: row 3, kk = 2 -> group 1, offset 0; kk = 3 -> offset 1.
        assert_eq!(at(0, 1, 3, 0), q[3 * k + 2]);
        assert_eq!(at(0, 1, 3, 1), q[3 * k + 3]);
        // Row 11 lives in the second 16-byte half of each group.
        assert_eq!(at(0, 0, 11, 0), q[11 * k]);
        // kk = 4 pairs with the zero pad column (kk = 5 >= k).
        assert_eq!(at(0, 2, 0, 0), q[4]);
        assert_eq!(at(0, 2, 0, 1), 0);
        // Panel 1 holds row 16; rows 17.. are zero padding.
        assert_eq!(at(1, 0, 0, 0), q[PACK_MR * k]);
        assert_eq!(at(1, 0, 1, 0), 0);
    }

    #[test]
    fn q8q_quad_panel_layout_and_padding() {
        // The VNNI/sdot layout: k = 5 -> kp = 8 (rounded to a quad),
        // 64-byte groups of row-major k-quads.
        let (m, k) = (PACK_MR + 1, 5);
        let q: Vec<i8> = (0..m * k).map(|i| (i % 127) as i8).collect();
        let (panels, kp) = pack_panels_q8q_quad(&q, m, k);
        assert_eq!(kp, 8);
        assert_eq!(panels.len(), 2 * PACK_MR * kp);
        let at = |pi: usize, g: usize, r: usize, j: usize| {
            panels[pi * PACK_MR * kp + g * 64 + r * 4 + j]
        };
        for pi in 0..2 {
            for g in 0..kp / 4 {
                for r in 0..PACK_MR {
                    for j in 0..4 {
                        let row = pi * PACK_MR + r;
                        let kk = 4 * g + j;
                        let want = if row < m && kk < k { q[row * k + kk] } else { 0 };
                        assert_eq!(at(pi, g, r, j), want, "p{pi} g{g} r{r} j{j}");
                    }
                }
            }
        }
    }

    #[test]
    fn vnni_demotes_past_its_exactness_bound() {
        // Only runnable where the Vnni tier is constructible at all.
        if !Simd::Vnni.runs_on(kernels::detect_host()) {
            return;
        }
        let (m, k) = (PACK_MR, VNNI_Q8_MAX_K + 1);
        let q = vec![1i8; m * k];
        let scales = vec![1.0f32; m];
        let pq = PackedQuantGemm::with_dispatch_q8q(&q, &scales, m, k, Simd::Vnni, 0);
        assert_eq!(pq.simd(), Simd::Avx2, "K past the u8xs8 bound must demote");
        // In range: the tier sticks and the panels are quad-packed.
        let k = 8;
        let q = vec![1i8; m * k];
        let pq = PackedQuantGemm::with_dispatch_q8q(&q, &scales, m, k, Simd::Vnni, 0);
        assert_eq!(pq.simd(), Simd::Vnni);
    }

    #[test]
    fn vnni_row_corrections_are_128_row_sums() {
        let (m, k) = (PACK_MR + 2, 7);
        let q: Vec<i8> = (0..m * k).map(|i| ((i * 11) % 255) as u8 as i8).collect();
        let corr = vnni_row_corrections(&q, m, k);
        assert_eq!(corr.len(), 2 * PACK_MR);
        for r in 0..m {
            let sum: i32 = q[r * k..(r + 1) * k].iter().map(|&w| i32::from(w)).sum();
            assert_eq!(corr[r], 128 * sum, "row {r}");
        }
        // Pad rows correct nothing (their weights are zero).
        for r in m..2 * PACK_MR {
            assert_eq!(corr[r], 0);
        }
    }

    #[test]
    fn q8q_matmul_matches_scalar_integer_oracle() {
        // The full q8q pipeline (dynamic per-column quantization ->
        // integer kernel -> fused dequant) against a from-scratch scalar
        // reference that re-derives the quantization independently.
        let (m, k, n) = (24usize, 19usize, 6usize);
        let mut rng = Rng::new(3);
        let mut a = vec![0.0; m * k];
        rng.fill_normal(&mut a, 0.1);
        let mut q = vec![0i8; m * k];
        let mut scales = vec![0.0f32; m];
        for r in 0..m {
            let row = &a[r * k..(r + 1) * k];
            let max = row.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
            let s = if max > 0.0 { max / 127.0 } else { 1.0 };
            scales[r] = s;
            for (dst, &v) in q[r * k..(r + 1) * k].iter_mut().zip(row) {
                *dst = (v / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut x, 1.0);

        let pq = PackedQuantGemm::with_dispatch_q8q(&q, &scales, m, k, Simd::Portable, 0);
        let bias: Vec<f32> = (0..m).map(|r| r as f32 * 0.01).collect();
        let mut got = vec![0.0; m * n];
        let mut scratch = QuantScratch::new();
        pq.matmul_q8q(&mut got, &x, n, false, &Epilogue::with_bias(&bias), &mut scratch);

        for j in 0..n {
            let frame = &x[j * k..(j + 1) * k];
            let max = frame.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
            let sx = if max > 0.0 { max / 127.0 } else { 1.0 };
            assert_eq!(scratch.col_scales()[j], sx);
            let xq: Vec<i32> = frame
                .iter()
                .map(|&v| (v / sx).round().clamp(-127.0, 127.0) as i32)
                .collect();
            for r in 0..m {
                let acc: i32 = (0..k).map(|c| i32::from(q[r * k + c]) * xq[c]).sum();
                let want = acc as f32 * (scales[r] * sx) + bias[r];
                let g = got[r * n + j];
                let tol = 1e-5 * want.abs().max(1.0);
                assert!((g - want).abs() <= tol, "({r},{j}): {g} vs {want}");
            }
        }
    }

    #[test]
    fn q8q_crossover_routes_small_n_to_widening_path() {
        let (m, k) = (32usize, 21usize);
        let mut rng = Rng::new(13);
        let mut a = vec![0.0; m * k];
        rng.fill_normal(&mut a, 0.2);
        let mut q = vec![0i8; m * k];
        let mut scales = vec![0.0f32; m];
        for r in 0..m {
            let row = &a[r * k..(r + 1) * k];
            let max = row.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
            let s = if max > 0.0 { max / 127.0 } else { 1.0 };
            scales[r] = s;
            for (dst, &v) in q[r * k..(r + 1) * k].iter_mut().zip(row) {
                *dst = (v / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
        let crossed = PackedQuantGemm::with_dispatch_q8q(&q, &scales, m, k, Simd::Portable, 2);
        assert_eq!(crossed.int_cutoff(), 2);
        assert_eq!(crossed.min_int_n(), 3);
        let plain = PackedQuantGemm::new(&q, &scales, m, k);
        let mut scratch = QuantScratch::new();
        for n in [1usize, 2] {
            // Below the crossover: q8q must take the widening path and
            // match it bitwise (exact same code runs).
            let mut x = vec![0.0; n * k];
            rng.fill_normal(&mut x, 1.0);
            let mut via_q8q = vec![0.0; m * n];
            let mut via_widen = vec![0.0; m * n];
            crossed.matmul_q8q(&mut via_q8q, &x, n, false, &Epilogue::NONE, &mut scratch);
            plain.matmul(&mut via_widen, &x, n, false, &Epilogue::NONE);
            assert_eq!(via_q8q, via_widen, "n={n} must route to widening");
        }
        // Above it: integer path, close to (but generally not equal to)
        // the widening result.
        let n = 4;
        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut x, 1.0);
        let mut via_q8q = vec![0.0; m * n];
        let mut via_widen = vec![0.0; m * n];
        crossed.matmul_q8q(&mut via_q8q, &x, n, false, &Epilogue::NONE, &mut scratch);
        plain.matmul(&mut via_widen, &x, n, false, &Epilogue::NONE);
        for (g, w) in via_q8q.iter().zip(&via_widen) {
            assert!((g - w).abs() < 0.1, "{g} vs {w}");
        }
    }

    #[test]
    fn q8q_drops_widening_panels_and_dequant_still_reads() {
        // Below the probe threshold `int_cutoff` is 0, so `new_q8q`
        // drops the widening copy; `dequant` must fall back to the
        // pair-interleaved layout and `matmul_q8q` must serve every n.
        let (m, k) = (PACK_MR + 3, 5);
        let q: Vec<i8> = (0..m * k).map(|i| ((i * 7) % 255) as u8 as i8).collect();
        let scales: Vec<f32> = (0..m).map(|r| 0.01 + r as f32 * 1e-3).collect();
        let pq = PackedQuantGemm::new_q8q(&q, &scales, m, k);
        assert!(pq.quantizes_activations());
        assert_eq!(pq.int_cutoff(), 0);
        for r in [0usize, 7, m - 1] {
            for c in [0usize, 2, k - 1] {
                assert_eq!(pq.dequant(r, c), f32::from(q[r * k + c]) * scales[r]);
            }
        }
        let x = vec![0.5f32; k];
        let mut out = vec![0.0; m];
        let mut scratch = QuantScratch::new();
        pq.matmul_q8q(&mut out, &x, 1, false, &Epilogue::NONE, &mut scratch);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantize_frames_zero_and_padding_conventions() {
        let (n, k) = (2usize, 3usize);
        let kp = 4;
        let x = [0.0f32, 0.0, 0.0, 2.0, -4.0, 1.0];
        let mut s = QuantScratch::new();
        quantize_frames(&x, n, k, kp, &mut s);
        // Zero frame: scale 1.0, all-zero quants.
        assert_eq!(s.cscale[0], 1.0);
        assert_eq!(&s.qx[..kp], &[0i8, 0, 0, 0]);
        // Second frame: max 4 -> scale 4/127; -4 maps to -127 exactly;
        // the kp pad byte stays 0.
        assert_eq!(s.cscale[1], 4.0 / 127.0);
        assert_eq!(s.qx[kp + 1], -127);
        assert_eq!(s.qx[kp + 3], 0);
        // qpair packs little-endian i16 pairs.
        let x0 = s.qx[kp] as i16 as u16 as u32;
        let x1 = s.qx[kp + 1] as i16 as u16 as u32;
        assert_eq!(s.qpair[kp / 2] as u32, x0 | (x1 << 16));
        // qshift is the same quant stream in the +128 u8 domain (the
        // vpdpbusd operand); pad bytes sit at the zero point 128.
        assert_eq!(s.qshift.len(), n * kp);
        for (i, (&sv, &qv)) in s.qshift.iter().zip(&s.qx).enumerate() {
            assert_eq!(sv, (qv as u8).wrapping_add(128), "byte {i}");
        }
        assert_eq!(s.qshift[kp + 3], 128);
    }

    #[test]
    fn q4_panel_layout_nibbles_and_padding() {
        // m = 17 rows (one full panel + 1), k = 5 (odd -> kp = 6 with a
        // zero pad nibble).  Check signed-nibble placement.
        let (m, k) = (PACK_MR + 1, 5);
        let q: Vec<i8> = (0..m * k).map(|i| (i % 15) as i8 - 7).collect();
        let (panels, kp) = pack_panels_q4(&q, m, k);
        assert_eq!(kp, 6);
        assert_eq!(panels.len(), 2 * (PACK_MR / 2) * kp);
        let nib = |pi: usize, g: usize, r: usize, o: usize| -> i8 {
            let b = panels[pi * (PACK_MR / 2) * kp + g * 16 + r];
            if o == 0 {
                ((b << 4) as i8) >> 4
            } else {
                (b as i8) >> 4
            }
        };
        // Panel 0: row 3, kk = 2 -> group 1, lo nibble; kk = 3 -> hi.
        assert_eq!(nib(0, 1, 3, 0), q[3 * k + 2]);
        assert_eq!(nib(0, 1, 3, 1), q[3 * k + 3]);
        // kk = 4 pairs with the zero pad column (kk = 5 >= k).
        assert_eq!(nib(0, 2, 0, 0), q[4]);
        assert_eq!(nib(0, 2, 0, 1), 0);
        // Panel 1 holds row 16; rows 17.. are zero padding.
        assert_eq!(nib(1, 0, 0, 0), q[PACK_MR * k]);
        assert_eq!(nib(1, 0, 1, 0), 0);
    }

    #[test]
    fn q4_quad_panel_layouts_vnni_and_sdot() {
        // Both quad q4 layouts pack the same nibbles — the same row's
        // k-quad as two bytes at `grp_base[r/4] + 2 * (r%4)` — and
        // differ only in the group-quarter order that makes each ISA's
        // in-register unpack shuffle-free.
        let (m, k) = (PACK_MR + 1, 6);
        let q: Vec<i8> = (0..m * k).map(|i| ((i * 5) % 15) as i8 - 7).collect();
        for grp_base in [VNNI_Q4_GRP_BASE, SDOT_Q4_GRP_BASE] {
            let (panels, kp) = pack_panels_q4_quad(&q, m, k, grp_base);
            assert_eq!(kp, 8);
            assert_eq!(panels.len(), 2 * (PACK_MR / 2) * kp);
            for pi in 0..2 {
                for g in 0..kp / 4 {
                    for r in 0..PACK_MR {
                        for j in 0..4 {
                            let byte = panels[pi * (PACK_MR / 2) * kp
                                + g * 32
                                + grp_base[r / 4]
                                + 2 * (r % 4)
                                + j / 2];
                            let got = if j % 2 == 0 {
                                ((byte << 4) as i8) >> 4
                            } else {
                                (byte as i8) >> 4
                            };
                            let row = pi * PACK_MR + r;
                            let kk = 4 * g + j;
                            let want = if row < m && kk < k { q[row * k + kk] } else { 0 };
                            assert_eq!(got, want, "base{:?} p{pi} g{g} r{r} j{j}", grp_base);
                        }
                    }
                }
            }
        }
    }

    fn quantize_rows_q4(a: &[f32], m: usize, k: usize) -> (Vec<i8>, Vec<f32>) {
        let mut q = vec![0i8; m * k];
        let mut scales = vec![0.0f32; m];
        for r in 0..m {
            let row = &a[r * k..(r + 1) * k];
            let max = row.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
            let s = if max > 0.0 { max / 7.0 } else { 1.0 };
            scales[r] = s;
            for (dst, &v) in q[r * k..(r + 1) * k].iter_mut().zip(row) {
                *dst = (v / s).round().clamp(-7.0, 7.0) as i8;
            }
        }
        (q, scales)
    }

    #[test]
    fn q4_matmul_matches_scalar_integer_oracle() {
        // Full q4 pipeline (dynamic per-column activation quantization ->
        // nibble-unpack integer kernel -> fused dequant) against a
        // from-scratch scalar reference.
        let (m, k, n) = (24usize, 19usize, 6usize);
        let mut rng = Rng::new(5);
        let mut a = vec![0.0; m * k];
        rng.fill_normal(&mut a, 0.1);
        let (q, scales) = quantize_rows_q4(&a, m, k);
        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut x, 1.0);

        let pq = PackedQuantGemm::with_dispatch_q4(&q, &scales, m, k, Simd::Portable, 0);
        assert!(pq.is_q4() && pq.quantizes_activations());
        let bias: Vec<f32> = (0..m).map(|r| r as f32 * 0.01).collect();
        let mut got = vec![0.0; m * n];
        let mut scratch = QuantScratch::new();
        pq.matmul_q4(&mut got, &x, n, false, &Epilogue::with_bias(&bias), &mut scratch);

        for j in 0..n {
            let frame = &x[j * k..(j + 1) * k];
            let max = frame.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
            let sx = if max > 0.0 { max / 127.0 } else { 1.0 };
            let xq: Vec<i32> = frame
                .iter()
                .map(|&v| (v / sx).round().clamp(-127.0, 127.0) as i32)
                .collect();
            for r in 0..m {
                let acc: i32 = (0..k).map(|c| i32::from(q[r * k + c]) * xq[c]).sum();
                let want = acc as f32 * (scales[r] * sx) + bias[r];
                let g = got[r * n + j];
                let tol = 1e-5 * want.abs().max(1.0);
                assert!((g - want).abs() <= tol, "({r},{j}): {g} vs {want}");
            }
        }
    }

    #[test]
    fn q4_dequant_reads_nibble_panels_and_bytes_are_half() {
        let (m, k) = (PACK_MR + 3, 8);
        let q: Vec<i8> = (0..m * k).map(|i| (i % 15) as i8 - 7).collect();
        let scales: Vec<f32> = (0..m).map(|r| 0.01 + r as f32 * 1e-3).collect();
        let mut pq4 = PackedQuantGemm::with_dispatch_q4(&q, &scales, m, k, Simd::Portable, 0);
        // Simulate the dropped-widening-panels state of new_q4.
        pq4.panels = Vec::new();
        for r in [0usize, 7, m - 1] {
            for c in [0usize, 3, k - 1] {
                assert_eq!(pq4.dequant(r, c), f32::from(q[r * k + c]) * scales[r]);
            }
        }
        let pq8 = PackedQuantGemm::with_dispatch_q8q(&q, &scales, m, k, Simd::Portable, 0);
        // The test matrix has a few scattered zeros but no zero block.
        assert_eq!(pq4.density(), 1.0);
        assert_eq!(pq4.panel_weight_bytes(), m * k / 2);
        assert_eq!(pq8.panel_weight_bytes(), m * k);
        assert_eq!(
            pq4.weight_bytes() - scales.len() * 4,
            (pq8.weight_bytes() - scales.len() * 4) / 2
        );
    }

    #[test]
    fn panel_mask_records_zero_blocks_and_dense_is_none() {
        let (m, k) = (PACK_MR * 2, SPARSE_KB * 3 + 5);
        let mut a = vec![1.0f32; m * k];
        assert!(PanelMask::from_f32(&a, m, k).is_none(), "dense -> no mask");
        // Zero panel 1's block 2 (rows 16.., k in [64, 96)) and panel
        // 0's ragged tail block 3 (k in [96, 101)).
        for r in PACK_MR..m {
            for kk in 2 * SPARSE_KB..3 * SPARSE_KB {
                a[r * k + kk] = 0.0;
            }
        }
        for r in 0..PACK_MR {
            for kk in 3 * SPARSE_KB..k {
                a[r * k + kk] = 0.0;
            }
        }
        let pm = PanelMask::from_f32(&a, m, k).expect("two zero blocks");
        assert_eq!(pm.blocks_per_panel(), 4);
        assert_eq!(pm.total_blocks(), 8);
        assert_eq!(pm.active_blocks(), 6);
        assert!((pm.density() - 0.75).abs() < 1e-12);
        let (bits, wpp) = pm.for_kernels();
        assert_eq!(wpp, 1);
        assert_eq!(bits[0] & 0b1111, 0b0111); // panel 0: block 3 clear
        assert_eq!(bits[1] & 0b1111, 0b1011); // panel 1: block 2 clear
        // A -0.0 weight keeps its block active (skip must stay exact).
        let mut b = a.clone();
        b[PACK_MR * k + 2 * SPARSE_KB] = -0.0;
        let pm2 = PanelMask::from_f32(&b, m, k).expect("still one zero block");
        assert_eq!(pm2.active_blocks(), 7);
    }

    #[test]
    fn sparse_skip_matches_dense_with_zeros_bitwise_f32() {
        // The skipped blocks hold exact zeros, so the masked sweep must
        // reproduce the dense sweep bit for bit.
        let (m, k, n) = (PACK_MR * 2 + 3, SPARSE_KB * 2 + 7, 5);
        let mut rng = Rng::new(21);
        let mut a = vec![0.0; m * k];
        rng.fill_normal(&mut a, 0.5);
        for r in 0..m {
            for kk in SPARSE_KB..2 * SPARSE_KB {
                a[r * k + kk] = 0.0;
            }
        }
        let mut x = vec![0.0; n * k];
        rng.fill_normal(&mut x, 1.0);
        let bias: Vec<f32> = (0..m).map(|r| (r % 3) as f32 * 0.1).collect();
        let acts = [Act::Sigmoid];
        let sparse = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 0);
        assert!(sparse.density() < 1.0);
        let mut dense = sparse.clone();
        dense.force_dense();
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        sparse.matmul(&mut c1, &x, n, false, &Epilogue::fused(&bias, &acts));
        dense.matmul(&mut c2, &x, n, false, &Epilogue::fused(&bias, &acts));
        for (g, w) in c1.iter().zip(&c2) {
            assert_eq!(g.to_bits(), w.to_bits(), "{g} vs {w}");
        }
    }

    #[test]
    fn sparse_skip_matches_dense_with_zeros_q8q_and_q4_i32() {
        let (m, k, n) = (PACK_MR * 2, SPARSE_KB * 2, 4);
        let mut q = vec![0i8; m * k];
        for (i, v) in q.iter_mut().enumerate() {
            *v = ((i * 5) % 15) as i8 - 7;
        }
        // Zero panel 0's block 1 and panel 1's block 0.
        for r in 0..PACK_MR {
            for kk in SPARSE_KB..k {
                q[r * k + kk] = 0;
            }
        }
        for r in PACK_MR..m {
            for kk in 0..SPARSE_KB {
                q[r * k + kk] = 0;
            }
        }
        let scales = vec![0.02f32; m];
        let mut x = vec![0.0; n * k];
        let mut rng = Rng::new(33);
        rng.fill_normal(&mut x, 1.0);
        let mut scratch = QuantScratch::new();
        for q4 in [false, true] {
            let sparse = if q4 {
                PackedQuantGemm::with_dispatch_q4(&q, &scales, m, k, Simd::Portable, 0)
            } else {
                PackedQuantGemm::with_dispatch_q8q(&q, &scales, m, k, Simd::Portable, 0)
            };
            assert!((sparse.density() - 0.5).abs() < 1e-12);
            let mut dense = sparse.clone();
            dense.force_dense();
            let mut c1 = vec![0i32; m * n];
            let mut c2 = vec![0i32; m * n];
            sparse.matmul_i32(&mut c1, &x, n, &mut scratch);
            dense.matmul_i32(&mut c2, &x, n, &mut scratch);
            assert_eq!(c1, c2, "q4={q4}: skip must be exact");
        }
    }

    #[test]
    fn bt_crossover_path_matches_packed_path() {
        let mut rng = Rng::new(11);
        let (m, k) = (40, 65);
        let mut a = vec![0.0; m * k];
        rng.fill_normal(&mut a, 0.5);
        let bias: Vec<f32> = (0..m).map(|r| (r % 5) as f32 * 0.1).collect();
        let acts = [Act::Sigmoid];
        let packed = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 0);
        let crossed = PackedGemm::with_dispatch(&a, m, k, Simd::Portable, 8);
        for n in [1usize, 4, 8] {
            let mut x = vec![0.0; n * k];
            rng.fill_normal(&mut x, 1.0);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            packed.matmul(&mut c1, &x, n, false, &Epilogue::fused(&bias, &acts));
            crossed.matmul(&mut c2, &x, n, false, &Epilogue::fused(&bias, &acts));
            for (g, w) in c1.iter().zip(&c2) {
                assert!((g - w).abs() < 1e-4, "n={n}: {g} vs {w}");
            }
        }
    }
}
