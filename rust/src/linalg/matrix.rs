//! Dense row-major f32 matrix — the only tensor type the native engine
//! needs.  Deliberately minimal: the hot paths (`gemm`, `gemv`) operate on
//! raw slices; `Matrix` is the owning container with shape checking.

use crate::util::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(
            data.len(),
            rows * cols,
            "data length {} != {rows}x{cols}",
            data.len()
        );
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Glorot-uniform init matching `python/compile/model.py::_glorot`
    /// in distribution (not bit-exact — bit-exact weights come from the
    /// exported bundles; this is for self-contained tests/benches).
    pub fn glorot(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let scale = (6.0 / (rows + cols) as f32).sqrt();
        let mut data = vec![0.0; rows * cols];
        rng.fill_uniform(&mut data, -scale, scale);
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transposed(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.at(r, c));
            }
        }
        out
    }

    /// Max |a - b| over all elements (test helper).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Transpose a `[t, d]` row-major block into a `[d, t]` column-per-step
/// buffer (the GEMM-friendly layout; see DESIGN.md §7).  `out` must be
/// `d * t` long.
pub fn transpose_into(x: &[f32], t: usize, d: usize, out: &mut [f32]) {
    assert_eq!(x.len(), t * d, "input is not t*d");
    assert_eq!(out.len(), t * d, "output is not d*t");
    // Blocked transpose: 16x16 tiles keep both streams cache-resident.
    const B: usize = 16;
    for r0 in (0..t).step_by(B) {
        for c0 in (0..d).step_by(B) {
            for r in r0..(r0 + B).min(t) {
                let src = &x[r * d..];
                for c in c0..(c0 + B).min(d) {
                    out[c * t + r] = src[c];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn from_fn_and_transpose() {
        let m = Matrix::from_fn(2, 3, |r, c| (r * 10 + c) as f32);
        let t = m.transposed();
        assert_eq!(t.rows(), 3);
        for r in 0..2 {
            for c in 0..3 {
                assert_eq!(m.at(r, c), t.at(c, r));
            }
        }
    }

    #[test]
    #[should_panic(expected = "data length")]
    fn from_vec_checks_len() {
        Matrix::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn transpose_into_matches_naive() {
        let (t, d) = (7, 33);
        let x: Vec<f32> = (0..t * d).map(|i| i as f32).collect();
        let mut out = vec![0.0; t * d];
        transpose_into(&x, t, d, &mut out);
        for r in 0..t {
            for c in 0..d {
                assert_eq!(out[c * t + r], x[r * d + c]);
            }
        }
    }

    #[test]
    fn glorot_bounds() {
        let mut rng = Rng::new(1);
        let m = Matrix::glorot(64, 64, &mut rng);
        let scale = (6.0 / 128.0f32).sqrt();
        assert!(m.data().iter().all(|v| v.abs() <= scale));
        // Not all zero / not all equal.
        assert!(m.data().iter().any(|&v| v != m.data()[0]));
    }
}
