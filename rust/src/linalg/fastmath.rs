//! Fast vectorizable transcendentals for the recurrence remainder.
//!
//! After the GEMM optimizations (EXPERIMENTS.md §Perf) the element-wise
//! scan is ~40% of block time, dominated by libm `exp`/`tanh` calls that
//! the autovectorizer cannot touch.  These replacements are branch-free
//! (clamp + polynomial + exponent bit-assembly), so whole scan loops
//! vectorize.
//!
//! Two forms coexist and are **bit-identical** to each other:
//!
//! * the scalar fns ([`fast_exp`], [`fast_sigmoid`], [`fast_tanh`]) —
//!   the reference semantics every engine test is pinned to;
//! * explicit SIMD lanes ([`avx2`]/[`neon`], 8/4 values per call) behind
//!   the [`map_exp`]/[`map_sigmoid`]/[`map_tanh`] slice dispatchers and
//!   the `engine/recurrence.rs` chain kernels.
//!
//! Bit-identity holds because every lane performs the *same sequence of
//! correctly-rounded IEEE-754 single operations* as the scalar code: the
//! same clamp, the same round-to-nearest-even, the same two-step
//! Cody–Waite reduction, the same Horner evaluation with separate
//! mul/add (no FMA — contraction would change results), the same
//! exponent-bit assembly, and the same compare+blend where the scalar
//! code branches on sign (computing both sides and selecting gives the
//! value the taken branch would have produced).  The one documented
//! exclusion is NaN input: vector min/max order NaN differently than
//! scalar `clamp`, and gate pre-activations are never NaN.
//! `tests::simd_lanes_bitwise_match_scalar` sweeps the full f32 exponent
//! range over every tier the host supports.
//!
//! Accuracy (property-tested in this module):
//! * `fast_exp`:    relative error < 3e-7 over [-87, 87]
//! * `fast_sigmoid`: absolute error < 1e-6 everywhere
//! * `fast_tanh`:   absolute error < 1e-6 everywhere
//!
//! That is far below the 1e-4 tolerance of the JAX-parity tests, so the
//! engines use these unconditionally.

// This module is on the unsafe allowlist (tools/lint): the SIMD lanes
// need raw loads/stores and `#[target_feature]` calls.  Every unsafe
// block carries a `// SAFETY:` comment; the lint gate enforces it.
#![allow(unsafe_code)]

use super::kernels::Simd;

const LOG2_E: f32 = std::f32::consts::LOG2_E;
const LN_2_HI: f32 = 0.693_359_4; // ln2 split for extra precision
const LN_2_LO: f32 = -2.121_944_4e-4;

/// exp(x) via 2^n · P(r):  n = round(x·log2e), r = x − n·ln2 ∈ [−.35,.35],
/// P = degree-6 Taylor (rel. err ~1e-9 on the reduced range), 2^n glued
/// on through the f32 exponent bits.  Inputs are clamped to the finite
/// range so the bit assembly cannot overflow.
#[inline]
pub fn fast_exp(x: f32) -> f32 {
    let x = x.clamp(-87.0, 87.0);
    let n = (x * LOG2_E).round_ties_even();
    // Two-step Cody–Waite reduction keeps r accurate at large |x|.
    let r = (x - n * LN_2_HI) - n * LN_2_LO;
    // Horner, degree 6 (max rel err ~1e-9 on the reduced range).
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (1.0 / 6.0
                    + r * (1.0 / 24.0
                        + r * (1.0 / 120.0 + r * (1.0 / 720.0))))));
    // 2^n: bias the exponent field. n in [-126, 127] after the clamp.
    let bits = (((n as i32) + 127) as u32) << 23;
    p * f32::from_bits(bits)
}

/// Logistic sigmoid using `fast_exp` (abs err < 1e-6).
#[inline]
pub fn fast_sigmoid(x: f32) -> f32 {
    // For x >= 0: 1/(1+e^-x); mirrored for x < 0 to avoid catastrophic
    // cancellation — expressed branch-free via copysign-style selects
    // that LLVM turns into vector blends.
    let e = fast_exp(-x.abs());
    let pos = 1.0 / (1.0 + e);
    if x >= 0.0 {
        pos
    } else {
        1.0 - pos
    }
}

/// tanh(x) = 1 − 2/(e^{2x}+1), via `fast_exp` (abs err < 1e-6).
#[inline]
pub fn fast_tanh(x: f32) -> f32 {
    let e = fast_exp(-2.0 * x.abs());
    let t = 1.0 - 2.0 * e / (1.0 + e);
    if x >= 0.0 {
        t
    } else {
        -t
    }
}

/// In-place `fast_exp` over a slice, dispatched down the ISA ladder.
/// Bitwise identical to the scalar loop for every `simd` tier.
pub fn map_exp(simd: Simd, v: &mut [f32]) {
    match simd {
        #[cfg(target_arch = "x86_64")]
        Simd::Avx2 | Simd::Vnni => {
            // SAFETY: `detect()`/`runs_on()` only hand out Avx2/Vnni on
            // hosts with AVX2; both tiers share the f32 lane.
            unsafe { avx2::map_exp(v) }
        }
        #[cfg(target_arch = "aarch64")]
        Simd::Neon | Simd::Sdot => {
            // SAFETY: `detect()`/`runs_on()` only hand out Neon/Sdot on
            // aarch64 hosts, where NEON is baseline.
            unsafe { neon::map_exp(v) }
        }
        _ => {
            for x in v.iter_mut() {
                *x = fast_exp(*x);
            }
        }
    }
}

/// In-place `fast_sigmoid` over a slice (see [`map_exp`]).
pub fn map_sigmoid(simd: Simd, v: &mut [f32]) {
    match simd {
        #[cfg(target_arch = "x86_64")]
        Simd::Avx2 | Simd::Vnni => {
            // SAFETY: Avx2/Vnni tiers imply AVX2 on this host.
            unsafe { avx2::map_sigmoid(v) }
        }
        #[cfg(target_arch = "aarch64")]
        Simd::Neon | Simd::Sdot => {
            // SAFETY: Neon/Sdot tiers imply NEON on this host.
            unsafe { neon::map_sigmoid(v) }
        }
        _ => {
            for x in v.iter_mut() {
                *x = fast_sigmoid(*x);
            }
        }
    }
}

/// In-place `fast_tanh` over a slice (see [`map_exp`]).
pub fn map_tanh(simd: Simd, v: &mut [f32]) {
    match simd {
        #[cfg(target_arch = "x86_64")]
        Simd::Avx2 | Simd::Vnni => {
            // SAFETY: Avx2/Vnni tiers imply AVX2 on this host.
            unsafe { avx2::map_tanh(v) }
        }
        #[cfg(target_arch = "aarch64")]
        Simd::Neon | Simd::Sdot => {
            // SAFETY: Neon/Sdot tiers imply NEON on this host.
            unsafe { neon::map_tanh(v) }
        }
        _ => {
            for x in v.iter_mut() {
                *x = fast_tanh(*x);
            }
        }
    }
}

/// AVX2 8-lane mirrors of the scalar polynomials.  Same op order per
/// lane ⇒ bitwise-identical results (see the module doc).
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use super::{LN_2_HI, LN_2_LO, LOG2_E};
    use core::arch::x86_64::*;

    /// `_MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC`: the vector twin
    /// of scalar `round_ties_even`.
    const ROUND_NE: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

    /// 8-lane `fast_exp`.
    ///
    /// # Safety
    /// Caller must ensure the host supports AVX2 (the `Avx2`/`Vnni`
    /// dispatch tiers guarantee it).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn fast_exp_ps(x: __m256) -> __m256 {
        // clamp: identical to scalar `f32::clamp` for non-NaN input.
        let x = _mm256_min_ps(_mm256_max_ps(x, _mm256_set1_ps(-87.0)), _mm256_set1_ps(87.0));
        let n = _mm256_round_ps::<ROUND_NE>(_mm256_mul_ps(x, _mm256_set1_ps(LOG2_E)));
        let r = _mm256_sub_ps(
            _mm256_sub_ps(x, _mm256_mul_ps(n, _mm256_set1_ps(LN_2_HI))),
            _mm256_mul_ps(n, _mm256_set1_ps(LN_2_LO)),
        );
        // Horner, innermost-out; separate mul/add per level exactly as
        // the scalar expression evaluates (no FMA contraction).
        let mut p = _mm256_set1_ps(1.0 / 720.0);
        for c in [1.0 / 120.0, 1.0 / 24.0, 1.0 / 6.0, 0.5, 1.0, 1.0] {
            p = _mm256_add_ps(_mm256_set1_ps(c), _mm256_mul_ps(r, p));
        }
        // 2^n via exponent bits.  `n` is integral after the round, so
        // cvtps (round-to-nearest) equals the scalar `as i32` truncation.
        let bits = _mm256_slli_epi32::<23>(_mm256_add_epi32(
            _mm256_cvtps_epi32(n),
            _mm256_set1_epi32(127),
        ));
        _mm256_mul_ps(p, _mm256_castsi256_ps(bits))
    }

    /// 8-lane `fast_sigmoid`.  Computes both branch arms and blends on
    /// `x >= 0`, which yields exactly the scalar branch's value.
    ///
    /// # Safety
    /// Caller must ensure the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn fast_sigmoid_ps(x: __m256) -> __m256 {
        let sign_mask = _mm256_set1_ps(-0.0);
        let ax = _mm256_andnot_ps(sign_mask, x); // |x|
        // SAFETY: same target-feature context (AVX2 enabled here).
        let e = unsafe { fast_exp_ps(_mm256_xor_ps(ax, sign_mask)) }; // exp(-|x|)
        let one = _mm256_set1_ps(1.0);
        let pos = _mm256_div_ps(one, _mm256_add_ps(one, e));
        let neg = _mm256_sub_ps(one, pos);
        let ge = _mm256_cmp_ps::<_CMP_GE_OQ>(x, _mm256_setzero_ps());
        _mm256_blendv_ps(neg, pos, ge)
    }

    /// 8-lane `fast_tanh`.  The sign is resolved by the same `x >= 0`
    /// compare the scalar code branches on (NOT a sign-bit copy: scalar
    /// `-0.0 >= 0.0` is true, so `fast_tanh(-0.0)` is `+0.0`).
    ///
    /// # Safety
    /// Caller must ensure the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn fast_tanh_ps(x: __m256) -> __m256 {
        let sign_mask = _mm256_set1_ps(-0.0);
        let ax = _mm256_andnot_ps(sign_mask, x);
        // SAFETY: same target-feature context (AVX2 enabled here).
        let e = unsafe { fast_exp_ps(_mm256_mul_ps(_mm256_set1_ps(-2.0), ax)) };
        let one = _mm256_set1_ps(1.0);
        let t = _mm256_sub_ps(
            one,
            _mm256_div_ps(_mm256_mul_ps(_mm256_set1_ps(2.0), e), _mm256_add_ps(one, e)),
        );
        let nt = _mm256_xor_ps(t, sign_mask); // exact IEEE negation
        let ge = _mm256_cmp_ps::<_CMP_GE_OQ>(x, _mm256_setzero_ps());
        _mm256_blendv_ps(nt, t, ge)
    }

    macro_rules! map_impl {
        ($name:ident, $lane:ident, $scalar:path) => {
            /// In-place slice map; 8-wide main loop + scalar tail (the
            /// scalar fn IS the same op sequence, so the tail is also
            /// bitwise-identical).
            ///
            /// # Safety
            /// Caller must ensure the host supports AVX2.
            #[target_feature(enable = "avx2")]
            pub(crate) unsafe fn $name(v: &mut [f32]) {
                let main = v.len() / 8 * 8;
                let p = v.as_mut_ptr();
                let mut i = 0;
                while i < main {
                    // SAFETY: i + 8 <= v.len(), so the unaligned
                    // load/store stay inside the slice; AVX2 is enabled
                    // in this target-feature context for the lane call.
                    unsafe {
                        let x = _mm256_loadu_ps(p.add(i));
                        _mm256_storeu_ps(p.add(i), $lane(x));
                    }
                    i += 8;
                }
                for x in &mut v[main..] {
                    *x = $scalar(*x);
                }
            }
        };
    }

    map_impl!(map_exp, fast_exp_ps, super::fast_exp);
    map_impl!(map_sigmoid, fast_sigmoid_ps, super::fast_sigmoid);
    map_impl!(map_tanh, fast_tanh_ps, super::fast_tanh);
}

/// NEON 4-lane mirrors of the scalar polynomials (see [`avx2`]).
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use super::{LN_2_HI, LN_2_LO, LOG2_E};
    use core::arch::aarch64::*;

    /// 4-lane `fast_exp`.
    ///
    /// # Safety
    /// Caller must ensure the host supports NEON (baseline on aarch64;
    /// the `Neon`/`Sdot` dispatch tiers guarantee it).
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn fast_exp_ps(x: float32x4_t) -> float32x4_t {
        let x = vminq_f32(vmaxq_f32(x, vdupq_n_f32(-87.0)), vdupq_n_f32(87.0));
        // vrndnq = round-ties-even, same as the scalar reduction.
        let n = vrndnq_f32(vmulq_f32(x, vdupq_n_f32(LOG2_E)));
        let r = vsubq_f32(
            vsubq_f32(x, vmulq_f32(n, vdupq_n_f32(LN_2_HI))),
            vmulq_f32(n, vdupq_n_f32(LN_2_LO)),
        );
        // Separate mul/add per Horner level (no FMLA — fusing would
        // change results vs the scalar expression).
        let mut p = vdupq_n_f32(1.0 / 720.0);
        for c in [1.0 / 120.0, 1.0 / 24.0, 1.0 / 6.0, 0.5, 1.0, 1.0] {
            p = vaddq_f32(vdupq_n_f32(c), vmulq_f32(r, p));
        }
        // vcvtq truncates, exact on the integral `n` — same value as
        // the scalar `as i32`.
        let bits = vshlq_n_s32::<23>(vaddq_s32(vcvtq_s32_f32(n), vdupq_n_s32(127)));
        vmulq_f32(p, vreinterpretq_f32_s32(bits))
    }

    /// 4-lane `fast_sigmoid` (compute-both-arms + `x >= 0` select).
    ///
    /// # Safety
    /// Caller must ensure the host supports NEON.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn fast_sigmoid_ps(x: float32x4_t) -> float32x4_t {
        let ax = vabsq_f32(x);
        // SAFETY: same target-feature context (NEON enabled here).
        let e = unsafe { fast_exp_ps(vnegq_f32(ax)) };
        let one = vdupq_n_f32(1.0);
        let pos = vdivq_f32(one, vaddq_f32(one, e));
        let neg = vsubq_f32(one, pos);
        vbslq_f32(vcgeq_f32(x, vdupq_n_f32(0.0)), pos, neg)
    }

    /// 4-lane `fast_tanh` (`x >= 0` select, matching the scalar branch
    /// including at `-0.0`).
    ///
    /// # Safety
    /// Caller must ensure the host supports NEON.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn fast_tanh_ps(x: float32x4_t) -> float32x4_t {
        let ax = vabsq_f32(x);
        // SAFETY: same target-feature context (NEON enabled here).
        let e = unsafe { fast_exp_ps(vmulq_f32(vdupq_n_f32(-2.0), ax)) };
        let one = vdupq_n_f32(1.0);
        let t = vsubq_f32(
            one,
            vdivq_f32(vmulq_f32(vdupq_n_f32(2.0), e), vaddq_f32(one, e)),
        );
        vbslq_f32(vcgeq_f32(x, vdupq_n_f32(0.0)), t, vnegq_f32(t))
    }

    macro_rules! map_impl {
        ($name:ident, $lane:ident, $scalar:path) => {
            /// In-place slice map; 4-wide main loop + bitwise-identical
            /// scalar tail.
            ///
            /// # Safety
            /// Caller must ensure the host supports NEON.
            #[target_feature(enable = "neon")]
            pub(crate) unsafe fn $name(v: &mut [f32]) {
                let main = v.len() / 4 * 4;
                let p = v.as_mut_ptr();
                let mut i = 0;
                while i < main {
                    // SAFETY: i + 4 <= v.len(), so the load/store stay
                    // inside the slice; NEON is enabled in this
                    // target-feature context for the lane call.
                    unsafe {
                        let x = vld1q_f32(p.add(i));
                        vst1q_f32(p.add(i), $lane(x));
                    }
                    i += 4;
                }
                for x in &mut v[main..] {
                    *x = $scalar(*x);
                }
            }
        };
    }

    map_impl!(map_exp, fast_exp_ps, super::fast_exp);
    map_impl!(map_sigmoid, fast_sigmoid_ps, super::fast_sigmoid);
    map_impl!(map_tanh, fast_tanh_ps, super::fast_tanh);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exp_relative_error() {
        let mut rng = Rng::new(1);
        for _ in 0..200_000 {
            let x = rng.uniform_in(-87.0, 87.0);
            let got = fast_exp(x) as f64;
            let want = (x as f64).exp();
            let rel = ((got - want) / want).abs();
            assert!(rel < 3e-7, "exp({x}): rel err {rel}");
        }
        // Edges and specials.
        assert!((fast_exp(0.0) - 1.0).abs() < 1e-7);
        assert!(fast_exp(-100.0) >= 0.0);
        assert!(fast_exp(100.0).is_finite());
    }

    #[test]
    fn sigmoid_absolute_error() {
        let mut rng = Rng::new(2);
        for _ in 0..200_000 {
            let x = rng.uniform_in(-40.0, 40.0);
            let got = fast_sigmoid(x) as f64;
            let want = 1.0 / (1.0 + (-(x as f64)).exp());
            assert!((got - want).abs() < 1e-6, "sigmoid({x})");
        }
        assert_eq!(fast_sigmoid(0.0), 0.5);
        assert!((fast_sigmoid(30.0) - 1.0).abs() < 1e-6);
        assert!(fast_sigmoid(-30.0) < 1e-6);
        // Symmetry (exactly mirrored by construction).
        for x in [0.3f32, 1.7, 5.5] {
            assert!((fast_sigmoid(-x) - (1.0 - fast_sigmoid(x))).abs() < 1e-7);
        }
    }

    #[test]
    fn tanh_absolute_error() {
        let mut rng = Rng::new(3);
        for _ in 0..200_000 {
            let x = rng.uniform_in(-20.0, 20.0);
            let got = fast_tanh(x) as f64;
            let want = (x as f64).tanh();
            assert!((got - want).abs() < 1e-6, "tanh({x}): {got} vs {want}");
        }
        assert_eq!(fast_tanh(0.0), 0.0);
        assert!((fast_tanh(15.0) - 1.0).abs() < 1e-6);
        // Odd function, exactly by construction.
        for x in [0.2f32, 2.0, 9.0] {
            assert_eq!(fast_tanh(-x), -fast_tanh(x));
        }
    }

    #[test]
    fn monotone_in_the_active_region() {
        // Gate semantics rely on monotonicity; verify on a fine grid.
        let mut prev_s = f32::NEG_INFINITY;
        let mut prev_t = f32::NEG_INFINITY;
        let mut x = -12.0f32;
        while x <= 12.0 {
            let s = fast_sigmoid(x);
            let t = fast_tanh(x);
            assert!(s >= prev_s, "sigmoid dip at {x}");
            assert!(t >= prev_t, "tanh dip at {x}");
            prev_s = s;
            prev_t = t;
            x += 1e-3;
        }
    }

    /// Every f32 binade (±2^e for the full exponent range, four
    /// mantissas each) plus zeros, denormals and infinities — the
    /// bitwise contract sweep.  NaN is the documented exclusion.  The
    /// length is deliberately not a multiple of the vector width so the
    /// scalar tail is exercised too.
    fn exponent_sweep() -> Vec<f32> {
        let mut v = vec![
            0.0f32,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            f32::from_bits(1), // smallest denormal
            -f32::from_bits(1),
            f32::MAX,
            f32::MIN,
        ];
        for e in -126i32..=127 {
            let b = (e as f32).exp2();
            for m in [1.0f32, 1.25, 1.5, 1.75] {
                v.push(b * m);
                v.push(-(b * m));
            }
        }
        assert!(v.len() % 8 != 0, "sweep must exercise the scalar tail");
        v
    }

    #[test]
    fn simd_lanes_bitwise_match_scalar() {
        let base = exponent_sweep();
        for tier in crate::linalg::supported_tiers() {
            for (name, mapper, scalar) in [
                ("exp", map_exp as fn(Simd, &mut [f32]), fast_exp as fn(f32) -> f32),
                ("sigmoid", map_sigmoid, fast_sigmoid),
                ("tanh", map_tanh, fast_tanh),
            ] {
                let mut got = base.clone();
                mapper(tier, &mut got);
                for (i, (&g, &x)) in got.iter().zip(base.iter()).enumerate() {
                    let want = scalar(x);
                    assert_eq!(
                        g.to_bits(),
                        want.to_bits(),
                        "{name}[{tier:?}] lane {i}: input {x:e} got {g:e} want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn odd_symmetry_full_sweep() {
        for &x in &exponent_sweep() {
            // Float == (not to_bits): fast_tanh(-0.0) is +0.0 while
            // -fast_tanh(0.0) is -0.0 — equal as floats, not as bits.
            assert_eq!(fast_tanh(-x), -fast_tanh(x), "tanh odd symmetry at {x:e}");
            assert_eq!(
                fast_sigmoid(-x),
                1.0 - fast_sigmoid(x),
                "sigmoid mirror at {x:e}"
            );
            if x > 0.0 {
                // Strictly positive inputs: the symmetry is exact down
                // to the bit pattern.
                assert_eq!(fast_tanh(-x).to_bits(), (-fast_tanh(x)).to_bits());
            }
        }
    }
}
