//! Fast vectorizable transcendentals for the recurrence remainder.
//!
//! After the GEMM optimizations (EXPERIMENTS.md §Perf) the element-wise
//! scan is ~40% of block time, dominated by libm `exp`/`tanh` calls that
//! the autovectorizer cannot touch.  These replacements are branch-free
//! (clamp + polynomial + exponent bit-assembly), so whole scan loops
//! vectorize.
//!
//! Accuracy (property-tested in this module):
//! * `fast_exp`:    relative error < 3e-7 over [-87, 87]
//! * `fast_sigmoid`: absolute error < 1e-6 everywhere
//! * `fast_tanh`:   absolute error < 1e-6 everywhere
//!
//! That is far below the 1e-4 tolerance of the JAX-parity tests, so the
//! engines use these unconditionally.

const LOG2_E: f32 = std::f32::consts::LOG2_E;
const LN_2_HI: f32 = 0.693_359_4; // ln2 split for extra precision
const LN_2_LO: f32 = -2.121_944_4e-4;

/// exp(x) via 2^n · P(r):  n = round(x·log2e), r = x − n·ln2 ∈ [−.35,.35],
/// P = degree-6 Taylor (rel. err ~1e-9 on the reduced range), 2^n glued
/// on through the f32 exponent bits.  Inputs are clamped to the finite
/// range so the bit assembly cannot overflow.
#[inline]
pub fn fast_exp(x: f32) -> f32 {
    let x = x.clamp(-87.0, 87.0);
    let n = (x * LOG2_E).round_ties_even();
    // Two-step Cody–Waite reduction keeps r accurate at large |x|.
    let r = (x - n * LN_2_HI) - n * LN_2_LO;
    // Horner, degree 6 (max rel err ~1e-9 on the reduced range).
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (1.0 / 6.0
                    + r * (1.0 / 24.0
                        + r * (1.0 / 120.0 + r * (1.0 / 720.0))))));
    // 2^n: bias the exponent field. n in [-126, 127] after the clamp.
    let bits = (((n as i32) + 127) as u32) << 23;
    p * f32::from_bits(bits)
}

/// Logistic sigmoid using `fast_exp` (abs err < 1e-6).
#[inline]
pub fn fast_sigmoid(x: f32) -> f32 {
    // For x >= 0: 1/(1+e^-x); mirrored for x < 0 to avoid catastrophic
    // cancellation — expressed branch-free via copysign-style selects
    // that LLVM turns into vector blends.
    let e = fast_exp(-x.abs());
    let pos = 1.0 / (1.0 + e);
    if x >= 0.0 {
        pos
    } else {
        1.0 - pos
    }
}

/// tanh(x) = 1 − 2/(e^{2x}+1), via `fast_exp` (abs err < 1e-6).
#[inline]
pub fn fast_tanh(x: f32) -> f32 {
    let e = fast_exp(-2.0 * x.abs());
    let t = 1.0 - 2.0 * e / (1.0 + e);
    if x >= 0.0 {
        t
    } else {
        -t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exp_relative_error() {
        let mut rng = Rng::new(1);
        for _ in 0..200_000 {
            let x = rng.uniform_in(-87.0, 87.0);
            let got = fast_exp(x) as f64;
            let want = (x as f64).exp();
            let rel = ((got - want) / want).abs();
            assert!(rel < 3e-7, "exp({x}): rel err {rel}");
        }
        // Edges and specials.
        assert!((fast_exp(0.0) - 1.0).abs() < 1e-7);
        assert!(fast_exp(-100.0) >= 0.0);
        assert!(fast_exp(100.0).is_finite());
    }

    #[test]
    fn sigmoid_absolute_error() {
        let mut rng = Rng::new(2);
        for _ in 0..200_000 {
            let x = rng.uniform_in(-40.0, 40.0);
            let got = fast_sigmoid(x) as f64;
            let want = 1.0 / (1.0 + (-(x as f64)).exp());
            assert!((got - want).abs() < 1e-6, "sigmoid({x})");
        }
        assert_eq!(fast_sigmoid(0.0), 0.5);
        assert!((fast_sigmoid(30.0) - 1.0).abs() < 1e-6);
        assert!(fast_sigmoid(-30.0) < 1e-6);
        // Symmetry (exactly mirrored by construction).
        for x in [0.3f32, 1.7, 5.5] {
            assert!((fast_sigmoid(-x) - (1.0 - fast_sigmoid(x))).abs() < 1e-7);
        }
    }

    #[test]
    fn tanh_absolute_error() {
        let mut rng = Rng::new(3);
        for _ in 0..200_000 {
            let x = rng.uniform_in(-20.0, 20.0);
            let got = fast_tanh(x) as f64;
            let want = (x as f64).tanh();
            assert!((got - want).abs() < 1e-6, "tanh({x}): {got} vs {want}");
        }
        assert_eq!(fast_tanh(0.0), 0.0);
        assert!((fast_tanh(15.0) - 1.0).abs() < 1e-6);
        // Odd function, exactly by construction.
        for x in [0.2f32, 2.0, 9.0] {
            assert_eq!(fast_tanh(-x), -fast_tanh(x));
        }
    }

    #[test]
    fn monotone_in_the_active_region() {
        // Gate semantics rely on monotonicity; verify on a fine grid.
        let mut prev_s = f32::NEG_INFINITY;
        let mut prev_t = f32::NEG_INFINITY;
        let mut x = -12.0f32;
        while x <= 12.0 {
            let s = fast_sigmoid(x);
            let t = fast_tanh(x);
            assert!(s >= prev_s, "sigmoid dip at {x}");
            assert!(t >= prev_t, "tanh dip at {x}");
            prev_s = s;
            prev_t = t;
            x += 1e-3;
        }
    }
}
