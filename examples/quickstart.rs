//! Quickstart: multi-time-step SRU inference in 40 lines.
//!
//! Builds the paper's small SRU (512 wide, ~1M params), runs the same
//! single-stream sequence at block size 1 and block size 16, verifies the
//! outputs are identical (the transformation is exact, not approximate),
//! and prints the wall-clock speedup — Table 1, row SRU-16, in miniature.
//!
//! Run: `cargo run --release --example quickstart`

use mtsrnn::engine::{Engine, SruEngine};
use mtsrnn::models::config::{Arch, ModelConfig, ModelSize};
use mtsrnn::models::SruParams;
use mtsrnn::util::{Rng, Timer};
use mtsrnn::workload::gaussian_frames;

fn main() {
    let cfg = ModelConfig::paper(Arch::Sru, ModelSize::Small);
    println!(
        "model: SRU-{} ({} params, {:.1} MiB of weights)",
        cfg.hidden,
        cfg.param_count(),
        cfg.weight_bytes() as f64 / (1024.0 * 1024.0)
    );

    let params = SruParams::init(&cfg, &mut Rng::new(2018));
    let steps = 512;
    let x = gaussian_frames(&mut Rng::new(7), steps, cfg.input, 1.0);

    // Single-step baseline (SRU-1): one GEMV pass per frame.
    let mut sru1 = SruEngine::new(params.clone(), 1);
    let mut out1 = vec![0.0; steps * cfg.hidden];
    let t = Timer::start();
    sru1.run_sequence(&x, steps, &mut out1);
    let ms1 = t.elapsed_ms();

    // Multi-time-step (SRU-16): one GEMM per 16 frames — each weight
    // fetched from DRAM once per 16 steps instead of once per step.
    let mut sru16 = SruEngine::new(params, 16);
    let mut out16 = vec![0.0; steps * cfg.hidden];
    let t = Timer::start();
    sru16.run_sequence(&x, steps, &mut out16);
    let ms16 = t.elapsed_ms();

    // The paper's key property: same numbers, different execution order.
    let max_diff = out1
        .iter()
        .zip(&out16)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "outputs diverged: {max_diff}");

    println!("steps          : {steps}");
    println!("SRU-1          : {ms1:.1} ms  ({:.3} ms/frame)", ms1 / steps as f64);
    println!("SRU-16         : {ms16:.1} ms  ({:.3} ms/frame)", ms16 / steps as f64);
    println!("speedup        : {:.0}%  (paper Table 1: 366.9% at T=16)", ms1 / ms16 * 100.0);
    println!("max |Δ| output : {max_diff:.2e}  (exact transformation)");
}
