//! Encoder–decoder example (paper Fig. 1c): the translation-shaped
//! workload.  A bidirectional SRU encoder (paper §2.1) compresses the
//! source sequence; its final state seeds a unidirectional decoder that
//! generates autoregressively.
//!
//! The paper's point shows up twice here:
//! * the **encoder** sees its whole input up front → multi-time-step
//!   blocks at full T (big win, like the acceptor);
//! * the **decoder** is autoregressive — each step consumes its own
//!   previous output, so T>1 is impossible for a single stream.  That is
//!   exactly the LSTM-dependency situation of §3.1, and the measured gap
//!   between encoder and decoder per-token cost demonstrates why the
//!   paper's technique targets input-driven RNNs.
//!
//! Run: `cargo run --release --example encoder_decoder`

use mtsrnn::engine::{BiDir, Engine, SruEngine};
use mtsrnn::linalg::{gemv, Matrix};
use mtsrnn::models::config::{Arch, ModelConfig};
use mtsrnn::models::SruParams;
use mtsrnn::util::{Rng, Timer};
use mtsrnn::workload::TokenStream;

const EMBED: usize = 128;
const HIDDEN: usize = 128;
const SRC_LEN: usize = 64;
const TGT_LEN: usize = 48;
const VOCAB: usize = 96;

fn sru(seed: u64, t: usize) -> SruEngine {
    let cfg = ModelConfig {
        arch: Arch::Sru,
        hidden: HIDDEN,
        input: HIDDEN,
    };
    SruEngine::new(SruParams::init(&cfg, &mut Rng::new(seed)), t)
}

fn main() {
    assert_eq!(EMBED, HIDDEN, "this demo keeps widths square");
    let mut ts = TokenStream::new(VOCAB, EMBED, 5);
    let (_, src) = ts.sequence(SRC_LEN);

    // ---------------- Encoder: bidirectional, full-T blocks -----------
    let mut enc_t1 = BiDir::new(sru(1, 1), sru(2, 1));
    let mut enc_blk = BiDir::new(sru(1, SRC_LEN), sru(2, SRC_LEN));
    let mut enc_out = vec![0.0; SRC_LEN * 2 * HIDDEN];

    let t = Timer::start();
    enc_t1.run_sequence(&src, SRC_LEN, &mut enc_out);
    let enc_ms_t1 = t.elapsed_ms();

    let mut enc_out_blk = vec![0.0; SRC_LEN * 2 * HIDDEN];
    let t = Timer::start();
    enc_blk.run_sequence(&src, SRC_LEN, &mut enc_out_blk);
    let enc_ms_blk = t.elapsed_ms();

    let max_d = enc_out
        .iter()
        .zip(&enc_out_blk)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_d < 1e-4, "encoder block equivalence: {max_d}");

    // Compress: mean over time of the concatenated features -> context.
    let mut context = vec![0.0f32; 2 * HIDDEN];
    for s in 0..SRC_LEN {
        for i in 0..2 * HIDDEN {
            context[i] += enc_out_blk[s * 2 * HIDDEN + i] / SRC_LEN as f32;
        }
    }

    // ---------------- Decoder: autoregressive, forced T=1 -------------
    // init state = projection of the context into the decoder cell.
    let mut rng = Rng::new(9);
    let proj = Matrix::glorot(HIDDEN, 2 * HIDDEN, &mut rng);
    let out_proj = Matrix::glorot(VOCAB, HIDDEN, &mut rng);
    let mut c0 = vec![0.0f32; HIDDEN];
    gemv(&mut c0, proj.data(), &context, HIDDEN, 2 * HIDDEN);

    let mut dec = sru(3, 1); // T=1: the recurrence through generated tokens
    dec.set_state(&c0);
    let mut y = vec![0.0f32; HIDDEN]; // embedded previous token (BOS = 0)
    let mut h = vec![0.0f32; HIDDEN];
    let mut logits = vec![0.0f32; VOCAB];
    let mut emb = vec![0.0f32; EMBED];
    let mut generated = Vec::with_capacity(TGT_LEN);

    let t = Timer::start();
    for _ in 0..TGT_LEN {
        dec.run_sequence(&y, 1, &mut h);
        gemv(&mut logits, out_proj.data(), &h, VOCAB, HIDDEN);
        // Greedy argmax.
        let tok = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        generated.push(tok);
        ts.embed(tok, &mut emb);
        y.copy_from_slice(&emb);
    }
    let dec_ms = t.elapsed_ms();

    println!("encoder–decoder (Fig. 1c): {SRC_LEN} src tokens -> {TGT_LEN} generated");
    println!(
        "encoder (bi-SRU) : T=1 {enc_ms_t1:.2} ms, T={SRC_LEN} {enc_ms_blk:.2} ms  ({:.0}% speedup, max|Δ|={max_d:.1e})",
        enc_ms_t1 / enc_ms_blk * 100.0
    );
    println!(
        "decoder (SRU)    : {dec_ms:.2} ms ({:.3} ms/token) — autoregressive, T=1 forced",
        dec_ms / TGT_LEN as f64
    );
    println!(
        "per-token cost   : encoder {:.1} µs vs decoder {:.1} µs  (the §3.1 dependency tax)",
        enc_ms_blk / SRC_LEN as f64 * 1e3,
        dec_ms / TGT_LEN as f64 * 1e3
    );
    println!("first 12 generated tokens: {:?}", &generated[..12]);
    assert!(generated.iter().all(|&t| t < VOCAB));
}
