//! RNN *acceptor* example (paper Fig. 1a): consume a whole token sequence,
//! emit one decision at the end — the sentiment-analysis pattern the paper
//! cites ("movie and restaurant reviews").
//!
//! A QRNN layer reads an embedded Zipf token stream; the final cell state
//! feeds a linear head.  The "reviews" are synthetic: positive documents
//! are biased toward one half of the vocabulary, negative toward the
//! other, and the head is *derived from labeled examples* (class-mean
//! centroids — nearest-centroid classification on the final state), so
//! the demo shows a real accept/reject decision, not noise.
//!
//! The paper's angle: an acceptor only needs outputs at the END of the
//! sequence, so multi-time-step blocks are pure win — latency of
//! intermediate frames is irrelevant, and T can be as large as the
//! document.  We measure exactly that.
//!
//! Run: `cargo run --release --example sentiment`

use mtsrnn::engine::{Engine, QrnnEngine};
use mtsrnn::models::config::{Arch, ModelConfig};
use mtsrnn::models::QrnnParams;
use mtsrnn::util::{Rng, Timer};
use mtsrnn::workload::TokenStream;

const VOCAB: usize = 64;
const EMBED: usize = 64;
const HIDDEN: usize = 128;
const DOC_LEN: usize = 96;

/// Draw one synthetic "review": positive docs sample tokens mostly from
/// the low half of the vocab, negative from the high half.
fn sample_doc(ts: &mut TokenStream, rng: &mut Rng, positive: bool) -> Vec<f32> {
    let mut x = vec![0.0; DOC_LEN * EMBED];
    let mut tok_buf = vec![0.0; EMBED];
    for s in 0..DOC_LEN {
        let mut t = ts.next_token();
        // Bias token identity by class (80/20).
        let in_class_half = rng.chance(0.8);
        let half = VOCAB / 2;
        t %= half;
        if positive != in_class_half {
            t += half;
        }
        ts.embed(t, &mut tok_buf);
        x[s * EMBED..(s + 1) * EMBED].copy_from_slice(&tok_buf);
    }
    x
}

/// Final cell state after reading a doc with block size `t_block`.
fn encode(params: &QrnnParams, x: &[f32], t_block: usize) -> Vec<f32> {
    let mut eng = QrnnEngine::new(params.clone(), t_block);
    let mut out = vec![0.0; DOC_LEN * HIDDEN];
    eng.run_sequence(x, DOC_LEN, &mut out);
    eng.state().0.to_vec()
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn main() {
    let cfg = ModelConfig {
        arch: Arch::Qrnn,
        hidden: HIDDEN,
        input: EMBED,
    };
    let params = QrnnParams::init(&cfg, &mut Rng::new(2018));
    let mut ts = TokenStream::new(VOCAB, EMBED, 3);
    let mut rng = Rng::new(9);

    // "Train" the head: class-mean centroids over 64 labeled examples.
    let mut centroid_pos = vec![0.0f32; HIDDEN];
    let mut centroid_neg = vec![0.0f32; HIDDEN];
    for i in 0..64 {
        let positive = i % 2 == 0;
        let x = sample_doc(&mut ts, &mut rng, positive);
        let state = encode(&params, &x, 32);
        let c = if positive { &mut centroid_pos } else { &mut centroid_neg };
        for (acc, v) in c.iter_mut().zip(&state) {
            *acc += v / 32.0;
        }
    }

    // Evaluate on 100 fresh docs, timing single- vs multi-time-step.
    let mut correct = 0;
    let mut ms_t1 = 0.0;
    let mut ms_t32 = 0.0;
    let trials = 100;
    for i in 0..trials {
        let positive = i % 2 == 0;
        let x = sample_doc(&mut ts, &mut rng, positive);

        let t = Timer::start();
        let s1 = encode(&params, &x, 1);
        ms_t1 += t.elapsed_ms();

        let t = Timer::start();
        let s32 = encode(&params, &x, 32);
        ms_t32 += t.elapsed_ms();

        // Multi-step must reach the same final state.
        let max_d = s1
            .iter()
            .zip(&s32)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d < 1e-4, "final state diverged: {max_d}");

        // Nearest-centroid decision.
        let score = dot(&s32, &centroid_pos) - dot(&s32, &centroid_neg);
        if (score > 0.0) == positive {
            correct += 1;
        }
    }

    let acc = correct as f64 / trials as f64;
    println!("acceptor: QRNN-{HIDDEN}, {VOCAB}-token vocab, {DOC_LEN}-token docs");
    println!("accuracy          : {:.0}% ({correct}/{trials})", acc * 100.0);
    println!("per-doc latency   : T=1  {:.3} ms", ms_t1 / trials as f64);
    println!("                    T=32 {:.3} ms  ({:.0}% speedup)", ms_t32 / trials as f64, ms_t1 / ms_t32 * 100.0);
    println!("(acceptors only need the final state -> multi-time-step is free)");
    assert!(acc > 0.8, "separable synthetic task should classify well");
}
