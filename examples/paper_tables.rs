//! Regenerate every table and figure from the paper in one run (reduced
//! sample count for a quick look; `cargo bench` / `mtsrnn tables` run the
//! full 1,024-sample protocol).
//!
//! Run: `cargo run --release --example paper_tables`

use mtsrnn::bench::tables::{
    ablation_dram, ablation_energy, ablation_lstm_precompute, figure_series, generate_table,
    PAPER_TABLES,
};
use mtsrnn::bench::{ascii_plot, BenchOpts};
use mtsrnn::models::config::{Arch, ModelSize};

fn main() {
    let samples = 256;
    let opts = BenchOpts {
        warmup_iters: 1,
        measure_iters: 2,
        max_seconds: 30.0,
    };
    println!("== Paper tables (reduced: {samples} samples, {} iters) ==\n", opts.measure_iters);
    for pt in &PAPER_TABLES {
        println!("{}", generate_table(pt, samples, &opts).render());
    }
    for (fig, arch) in [("5", Arch::Sru), ("6", Arch::Qrnn)] {
        println!(
            "{}",
            ascii_plot(
                &format!("Figure {fig}: {arch} speedup vs T (simulated)"),
                &figure_series(arch, samples),
            )
        );
    }
    println!("{}", ablation_dram(Arch::Sru, ModelSize::Large, samples).render());
    println!("{}", ablation_lstm_precompute(ModelSize::Small, samples, &opts).render());
    println!("{}", ablation_energy(Arch::Sru, ModelSize::Large, samples).render());
}
