//! End-to-end driver: a simulated **on-device ASR** service — the paper's
//! §1 motivating use case — running through the full L3 stack: workload
//! generator → coordinator (sessions + block batcher + adaptive policy) →
//! inference backend → latency/throughput report.
//!
//! A speech-like 40-dim feature stream (100 frames/sec, as real fbank
//! frontends produce) is fed to a 4-layer SRU-512 transducer.  We serve
//! the same trace three ways and report the latency/efficiency trade:
//!
//!   * T=1   — single-step (lowest latency, max DRAM traffic)
//!   * T=32  — fixed multi-time-step (the paper's headline configuration)
//!   * adaptive — the coordinator picks T from the arrival rate
//!
//! By default runs the native backend; pass `--pjrt` to execute the AOT
//! JAX/Pallas artifacts via PJRT instead (requires `make artifacts`).
//!
//! Run: `cargo run --release --example streaming_asr [-- --pjrt]`
//!      (results land in EXPERIMENTS.md §E2E)

use std::time::Duration;

use mtsrnn::coordinator::{
    BlockBackend, Coordinator, CoordinatorConfig, NativeBackend, PolicyMode,
};
use mtsrnn::engine::NativeStack;
use mtsrnn::models::config::{StackSpec, ASR_SRU};
use mtsrnn::models::StackParams;
use mtsrnn::runtime::{ArtifactDir, PjrtBackend};
use mtsrnn::util::{Rng, Timer};
use mtsrnn::workload::AsrTrace;

const SECONDS: usize = 8; // simulated audio length
const FPS: usize = 100; // frames per second
const FRAMES: usize = SECONDS * FPS;

fn serve_trace<B: BlockBackend>(
    label: &str,
    backend: B,
    policy: PolicyMode,
) -> (f64, f64, f64, Vec<f32>) {
    let mut coord = Coordinator::new(
        backend,
        CoordinatorConfig {
            policy,
            max_wait: Duration::from_millis(80),
            max_sessions: 8,
            ..Default::default()
        },
    );
    let mut trace = AsrTrace::new(40, 42);
    let frames = trace.frames(FRAMES);

    let id = coord.open().expect("open session");
    let timer = Timer::start();
    let mut logits = Vec::new();
    // Feed in 20ms chunks (2 frames), as a real audio callback would.
    for chunk in frames.chunks(2 * 40) {
        coord.feed(id, chunk).expect("feed");
        coord.tick().expect("tick");
        logits.extend(coord.drain(id, usize::MAX).expect("drain"));
    }
    logits.extend(coord.close(id).expect("close"));
    let wall_ms = timer.elapsed_ms();

    assert_eq!(logits.len(), FRAMES * 32, "one logit row per frame");
    let p50 = coord.metrics.latency_us.quantile_bound(0.5) / 1e3;
    let reduction = coord.metrics.traffic_reduction();
    println!(
        "{label:<10} wall {wall_ms:>8.1} ms   mean_T {:>5.1}   p50 frame latency {p50:>8.2} ms   weight-traffic ÷{reduction:.1}",
        coord.metrics.mean_block(),
    );
    (wall_ms, p50, reduction, logits)
}

fn main() {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    println!(
        "on-device ASR simulation: {SECONDS}s of audio @ {FPS} fps -> {} ({} params)\n",
        ASR_SRU.name(),
        ASR_SRU.param_count()
    );

    let native = |block: usize| {
        // The composable spec API: `sru:f32:512x4` == the legacy ASR_SRU
        // stack (try `lstm:f32:512x4` or `sru:q8:512x4` here — any spec
        // serves through the same coordinator path).
        let spec = StackSpec::parse("sru:f32:512x4").expect("builtin spec");
        let params = StackParams::init(&spec, &mut Rng::new(2018)).expect("init params");
        NativeBackend::new(NativeStack::new(&spec, params, block.max(32)).expect("build stack"))
    };

    let (_, _, _, base) = serve_trace("T=1", native(1), PolicyMode::Fixed(1));
    let (_, _, _, blocked) = serve_trace("T=32", native(32), PolicyMode::Fixed(32));
    let (_, _, _, adaptive) = serve_trace("adaptive", native(32), PolicyMode::Adaptive);

    // Serving-policy invariance: identical logits regardless of batching.
    let diff = |a: &[f32], b: &[f32]| {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    };
    println!(
        "\nlogit parity: T=1 vs T=32 max|Δ| = {:.2e}, T=1 vs adaptive = {:.2e}",
        diff(&base, &blocked),
        diff(&base, &adaptive)
    );
    assert!(diff(&base, &blocked) < 1e-3);
    assert!(diff(&base, &adaptive) < 1e-3);

    if use_pjrt {
        println!("\n--- PJRT backend (AOT JAX/Pallas artifacts) ---");
        let result = (|| -> Result<(), String> {
            let dir = ArtifactDir::load("artifacts")?;
            let backend = PjrtBackend::load(&dir, "asr_sru_512x4").map_err(|e| e.to_string())?;
            println!("platform: {}", backend.platform());
            let (_, _, _, pjrt_logits) = serve_trace("pjrt", backend, PolicyMode::Fixed(32));

            // Cross-backend parity requires the SAME weights: load the
            // JAX-exported bundle into the native engine too.
            let bundle = mtsrnn::weights::Bundle::load(dir.path_of("weights_asr_sru_512x4.bin"))
                .map_err(|e| e.to_string())?;
            let spec = StackSpec::from_config(&ASR_SRU);
            let params = StackParams::from_bundle(&bundle, &spec)?;
            let native_same = NativeBackend::new(NativeStack::new(&spec, params, 32)?);
            let (_, _, _, native_logits) =
                serve_trace("native*", native_same, PolicyMode::Fixed(32));
            println!(
                "cross-backend parity (same exported weights): max|Δ| = {:.2e}",
                diff(&native_logits, &pjrt_logits)
            );
            Ok(())
        })();
        if let Err(e) = result {
            println!("pjrt path unavailable ({e}); run `make artifacts`");
        }
    }
    println!("\ndone — see EXPERIMENTS.md §E2E for the recorded run");
}
