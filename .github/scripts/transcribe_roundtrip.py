#!/usr/bin/env python3
"""Serve-level golden conformance: `mtsrnn serve --batch auto` must
transcribe a golden fixture's frame stream bit-identically to the python
reference — the acceptance check of the streaming-ASR scenario, run over
real TCP against the release binary.

Reads a stack fixture from rust/tests/golden/ (spec, seed, block, input
frames, expected transcript), starts the server with exactly those
settings, speaks OPEN / DECODE / FEED / TRANSCRIBE final / POLL, and
asserts:

* the transcript token sequence equals the fixture's, exactly;
* every drained logit is within the fixture's tolerance.

Usage: transcribe_roundtrip.py <fixture.json> <port> [threads] [binary]
"""

import json
import socket
import subprocess
import sys
import time
from pathlib import Path


def connect(port: int, deadline_s: float = 60.0) -> socket.socket:
    deadline = time.time() + deadline_s
    while True:
        try:
            return socket.create_connection(("127.0.0.1", port), timeout=10)
        except OSError:
            if time.time() > deadline:
                raise
            time.sleep(0.2)


def main() -> None:
    fixture = Path(sys.argv[1])
    port = int(sys.argv[2])
    threads = sys.argv[3] if len(sys.argv) > 3 else "1"
    binary = sys.argv[4] if len(sys.argv) > 4 else "./target/release/mtsrnn"
    fx = json.loads(fixture.read_text())
    feat, vocab, frames, block = fx["feat"], fx["vocab"], fx["frames"], fx["block"]

    proc = subprocess.Popen(
        [
            binary,
            "serve",
            "--stack",
            fx["spec"],
            "--seed",
            str(fx["seed"]),
            "--port",
            str(port),
            "--block",
            str(block),
            # Cap dispatch size at the chunk too: a backlog must drain
            # as [block]*k dispatches, never one bigger fused block.
            "--max-block",
            str(block),
            # Deadline far away: dispatches are exactly [block] * k, so a
            # bidir stack's chunking matches the fixture's reference.
            "--max-wait-ms",
            "100000",
            "--batch",
            "auto",
            "--threads",
            threads,
        ],
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
    )
    try:
        sock = connect(port)
        sock.settimeout(30)
        f = sock.makefile("rw", newline="\n")

        def call(line: str) -> str:
            f.write(line + "\n")
            f.flush()
            resp = f.readline().strip()
            assert resp.startswith("OK"), f"{line.split()[0]} -> {resp!r}"
            return resp

        sid = call("OPEN").split()[1]
        call(f"DECODE {sid} greedy")
        # Feed whole blocks so each dispatch is one fixture chunk.
        x = fx["x"]
        for s in range(0, frames, block):
            vals = x[s * feat : (s + block) * feat]
            call(f"FEED {sid} " + " ".join(repr(v) for v in vals))

        resp = call(f"TRANSCRIBE {sid} final").split()
        n = int(resp[1])
        toks = [int(t) for t in resp[2:]]
        assert len(toks) == n
        assert toks == fx["tokens"], (
            f"transcript mismatch for {fx['spec']} (threads={threads}):\n"
            f"  served : {toks}\n  python : {fx['tokens']}"
        )

        got = []
        deadline = time.time() + 30
        while len(got) < frames * vocab and time.time() < deadline:
            parts = call(f"POLL {sid} 1000").split()
            got.extend(float(v) for v in parts[2:])
            if int(parts[1]) == 0:
                time.sleep(0.05)
        assert len(got) == frames * vocab, f"drained {len(got)} logit values"
        tol = fx["tolerance"]
        worst = max(abs(g - w) for g, w in zip(got, fx["logits"]))
        assert worst <= tol, f"logit drift {worst} > {tol}"

        call(f"CLOSE {sid}")
        f.write("QUIT\n")
        f.flush()
        print(
            f"transcribe OK: {fx['spec']} threads={threads} — "
            f"{n} tokens bit-identical to python, max logit diff {worst:.2e}"
        )
    except BaseException:
        proc.terminate()
        try:
            out, _ = proc.communicate(timeout=5)
            print(f"--- server output ---\n{out}", file=sys.stderr)
        except subprocess.TimeoutExpired:
            proc.kill()
        raise
    proc.terminate()
    try:
        proc.communicate(timeout=5)
    except subprocess.TimeoutExpired:
        proc.kill()


if __name__ == "__main__":
    main()
