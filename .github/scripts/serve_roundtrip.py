#!/usr/bin/env python3
"""Loopback smoke test for `mtsrnn serve --stack <spec>`.

Starts the TCP server with the given stack spec, speaks the wire
protocol as a client (OPEN / FEED / POLL / CLOSE / QUIT), and asserts a
full feed->drain round trip: every fed frame must come back as one
row of `vocab` finite logits.

Usage: serve_roundtrip.py <spec> <port> [binary]
"""

import socket
import subprocess
import sys
import time

FEAT, VOCAB, FRAMES = 40, 32, 8


def connect(port: int, deadline_s: float = 60.0) -> socket.socket:
    deadline = time.time() + deadline_s
    while True:
        try:
            return socket.create_connection(("127.0.0.1", port), timeout=10)
        except OSError:
            if time.time() > deadline:
                raise
            time.sleep(0.2)


def main() -> None:
    spec = sys.argv[1]
    port = int(sys.argv[2])
    binary = sys.argv[3] if len(sys.argv) > 3 else "./target/release/mtsrnn"
    proc = subprocess.Popen(
        [
            binary,
            "serve",
            "--stack",
            spec,
            "--port",
            str(port),
            "--block",
            "4",
            "--max-wait-ms",
            "5",
        ],
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
    )
    try:
        sock = connect(port)
        sock.settimeout(30)
        f = sock.makefile("rw", newline="\n")

        def call(line: str) -> str:
            f.write(line + "\n")
            f.flush()
            resp = f.readline().strip()
            assert resp.startswith("OK"), f"{line.split()[0]} -> {resp!r}"
            return resp

        sid = call("OPEN").split()[1]
        frame = " ".join(["0.25"] * FEAT)
        feed = " ".join([frame] * FRAMES)
        resp = call(f"FEED {sid} {feed}")
        assert resp == f"OK {FRAMES}", resp

        got = 0
        deadline = time.time() + 30
        while got < FRAMES * VOCAB and time.time() < deadline:
            parts = call(f"POLL {sid} 1000").split()
            n = int(parts[1])
            vals = [float(v) for v in parts[2:]]
            assert len(vals) == n, f"POLL advertised {n}, sent {len(vals)}"
            assert all(v == v and abs(v) != float("inf") for v in vals), "non-finite logit"
            got += n
            if n == 0:
                time.sleep(0.05)
        assert got == FRAMES * VOCAB, f"drained {got} of {FRAMES * VOCAB} logit values"

        call(f"CLOSE {sid}")
        f.write("QUIT\n")
        f.flush()
        print(f"smoke OK: {spec} served {FRAMES} frames x {VOCAB} logits over loopback")
    except BaseException:
        proc.terminate()
        try:
            out, _ = proc.communicate(timeout=5)
            print(f"--- server output ---\n{out}", file=sys.stderr)
        except subprocess.TimeoutExpired:
            proc.kill()
        raise
    proc.terminate()
    try:
        proc.communicate(timeout=5)
    except subprocess.TimeoutExpired:
        proc.kill()


if __name__ == "__main__":
    main()
