#!/usr/bin/env python3
"""Perf-regression gate for the CI bench job.

Compares the bench_out/*.json a CI run just produced against the
baselines committed at HEAD (read via `git show`, so a dirty working
tree cannot shadow them).  Any throughput field that dropped more than
REGRESSION_FRAC emits a GitHub `::error::` annotation and fails the
job — unless the committed baseline declares itself a seed or an
estimate (`"source"` containing "seed" or "estimate"), in which case
the file is compared warn-only: seed snapshots come from a developer
desktop, not the runner fleet, so failing against them would gate on a
host-class difference rather than a regression.  A baseline refreshed
from the CI `bench-json` artifact records a runner source string and
gates hard from then on.

The escape hatch for a legitimate change in performance (new kernel,
different runner class) is refreshing the committed baseline from the
run's artifact in the same PR — the diff then shows the old and new
numbers side by side for review.

Run from the `rust/` directory (the CI job's working-directory):

    python3 ../.github/scripts/bench_compare.py
"""

import json
import subprocess
import sys
from pathlib import Path

# A measured throughput this much below baseline (relative) fails.
REGRESSION_FRAC = 0.15

# Record fields that identify a measurement point across runs; the rest
# of a record is data.  `shape` is a list in the JSON, made hashable
# below.
ID_KEYS = (
    "m",
    "k",
    "t",
    "threads",
    "tier",
    "dot",
    "shape",
    "shards",
    "sessions",
    "cell",
    "h",
    "isa",
)


def is_throughput(key: str) -> bool:
    """Higher-is-better rate fields; ratios and byte counts are not."""
    return key.endswith("gflops") or key.endswith("fps")


def load_baseline(name: str):
    """The committed copy of bench_out/<name> at HEAD, or None."""
    try:
        proc = subprocess.run(
            ["git", "show", f"HEAD:rust/bench_out/{name}"],
            capture_output=True,
            text=True,
            check=True,
        )
        return json.loads(proc.stdout)
    except (subprocess.CalledProcessError, json.JSONDecodeError, OSError):
        return None


def is_advisory(doc: dict) -> bool:
    """Seed/estimate baselines (non-runner host class) are advisory,
    not gating; CI-refreshed baselines carry a runner source string."""
    src = str(doc.get("source", "")).lower()
    return "estimate" in src or "seed" in src


def records(doc: dict):
    """Yield ((field, identity), record) for every list-of-records
    field in a bench report (points, isa_tiers, acceptance, ...)."""
    for field, val in doc.items():
        if not (isinstance(val, list) and val and isinstance(val[0], dict)):
            continue
        for rec in val:
            ident = tuple(
                (k, tuple(rec[k]) if isinstance(rec[k], list) else rec[k])
                for k in ID_KEYS
                if k in rec
            )
            yield (field, ident), rec


def compare(name: str, fresh: dict, base: dict, gating: bool) -> int:
    flagged = 0
    level = "error" if gating else "warning"
    base_index = dict(records(base))
    for key, rec in records(fresh):
        baserec = base_index.get(key)
        if baserec is None:
            # New measurement point (e.g. a tier the baseline host did
            # not support) — nothing to compare against.
            continue
        for fld, got in rec.items():
            if not is_throughput(fld):
                continue
            want = baserec.get(fld)
            if not isinstance(want, (int, float)) or not isinstance(got, (int, float)):
                continue
            if want <= 0:
                continue
            drop = (want - got) / want
            if drop > REGRESSION_FRAC:
                field, ident = key
                where = " ".join(f"{k}={v}" for k, v in ident)
                print(
                    f"::{level} file=rust/bench_out/{name}::"
                    f"{name} {field}[{where}] {fld}: {got:.2f} is "
                    f"{drop:.0%} below committed baseline {want:.2f}"
                )
                flagged += 1
    return flagged


def main() -> int:
    out_dir = Path("bench_out")
    fresh_files = sorted(out_dir.glob("BENCH_*.json"))
    if not fresh_files:
        print("bench_compare: no bench_out/BENCH_*.json produced; nothing to do")
        return 0
    failures = 0
    warnings = 0
    for path in fresh_files:
        base = load_baseline(path.name)
        if base is None:
            print(f"bench_compare: no committed baseline for {path.name}; skipping")
            continue
        try:
            fresh = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            print(f"::warning::{path} is not valid JSON ({e}); skipping")
            continue
        gating = not is_advisory(base)
        n = compare(path.name, fresh, base, gating)
        mode = "gating" if gating else "seed/estimate baseline, warn-only"
        print(f"bench_compare: {path.name}: {n} regression(s) ({mode})")
        if gating:
            failures += n
        else:
            warnings += n
    if failures:
        print(
            f"bench_compare: FAIL — {failures} throughput point(s) >"
            f"{REGRESSION_FRAC:.0%} below committed baseline; refresh the"
            " baseline from this run's artifact if the change is intended"
        )
        return 1
    if warnings:
        print(
            f"bench_compare: {warnings} point(s) below seed/estimate"
            " baseline(s) (warn-only)"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
